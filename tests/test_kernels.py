"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.convcore import conv2d_int8, matmul_int8
from repro.kernels.convcore.ref import conv2d_int8_ref, matmul_int8_ref
from repro.kernels.postproc import postprocess
from repro.kernels.postproc.ref import postprocess_ref
from repro.kernels.swa import swa_attention
from repro.kernels.swa.ref import swa_attention_ref


def _int8(key, shape):
    return jax.random.randint(key, shape, -127, 128, jnp.int8)


# --------------------------------------------------------------------------
# convcore
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),      # single tile
    (256, 512, 256),      # multi-k accumulation
    (384, 640, 128),      # multiple M tiles
    (100, 200, 60),       # ragged (exercises padding)
    (1, 2048, 1000),      # FC-layer shape (YOLO head-ish)
])
@pytest.mark.parametrize("relu", [False, True])
def test_matmul_int8_vs_ref(m, k, n, relu):
    ka, kb, ks = jax.random.split(jax.random.PRNGKey(m * n), 3)
    a = _int8(ka, (m, k))
    b = _int8(kb, (k, n))
    scale = jax.random.uniform(ks, (n,), jnp.float32, 1e-4, 1e-2)
    bias = jax.random.normal(ks, (n,), jnp.float32)
    out = matmul_int8(a, b, scale, bias, relu=relu, out_dtype=jnp.float32,
                      interpret=True, bm=128, bn=128, bk=128)
    ref = matmul_int8_ref(a, b, scale, bias, relu=relu, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_matmul_int8_exact_int_accumulation():
    """int8 x int8 -> int32 must be exact (no float rounding in the MACs)."""
    key = jax.random.PRNGKey(0)
    a = _int8(key, (128, 256))
    b = _int8(jax.random.fold_in(key, 1), (256, 128))
    out = matmul_int8(a, b, jnp.ones((128,)), jnp.zeros((128,)),
                      out_dtype=jnp.float32, interpret=True,
                      bm=128, bn=128, bk=128)
    exact = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(out, np.int64), exact)


@pytest.mark.parametrize("hw,cin,cout,kk,stride,pad", [
    (8, 16, 32, 3, 1, 1),     # 3x3 same conv
    (16, 3, 8, 3, 2, 1),      # strided downsample (darknet)
    (8, 32, 16, 1, 1, 0),     # 1x1 bottleneck
])
def test_conv2d_int8_vs_ref(hw, cin, cout, kk, stride, pad):
    key = jax.random.PRNGKey(hw * cin)
    x = _int8(key, (2, hw, hw, cin))
    w = _int8(jax.random.fold_in(key, 1), (kk, kk, cin, cout))
    scale = jnp.full((cout,), 1e-3, jnp.float32)
    bias = jnp.zeros((cout,), jnp.float32)
    out = conv2d_int8(x, w, scale, bias, stride=stride, padding=pad,
                      relu=True, out_dtype=jnp.float32, interpret=True)
    ref = conv2d_int8_ref(x, w, scale, bias, stride=stride, padding=pad,
                          relu=True, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# postproc
# --------------------------------------------------------------------------
@pytest.mark.parametrize("h,w,c,act,pool", [
    (32, 32, 16, "relu", 1),
    (32, 32, 16, "relu", 2),
    (64, 64, 8, "sigmoid", 2),
    (30, 30, 8, "none", 2),     # ragged H/W with pooling
    (16, 16, 128, "tanh", 1),
])
def test_postproc_vs_ref(h, w, c, act, pool):
    if (h // pool) * pool != h:
        pytest.skip("pool must divide true size for shape parity")
    key = jax.random.PRNGKey(h + c)
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    scale = jax.random.uniform(jax.random.fold_in(key, 1), (c,), jnp.float32,
                               0.5, 2.0)
    bias = jax.random.normal(jax.random.fold_in(key, 2), (c,), jnp.float32)
    out = postprocess(x, scale, bias, act=act, pool=pool,
                      out_dtype=jnp.float32, interpret=True)
    ref = postprocess_ref(x, scale, bias, act=act, pool=pool,
                          out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# swa flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("s,window", [
    (128, 32),     # banded
    (128, 64),
    (256, 256),    # window == S: full causal flash attention
    (96, 32),      # ragged S (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_vs_ref(s, window, dtype):
    key = jax.random.PRNGKey(s + window)
    b, hq, hkv, d = 2, 4, 2, 32
    q = jax.random.normal(key, (b, s, hq, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d), dtype)
    out = swa_attention(q, k, v, window=window, block=32, interpret=True)
    kx = jnp.repeat(k, hq // hkv, axis=2)
    vx = jnp.repeat(v, hq // hkv, axis=2)

    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * hq, s, d)

    ref = swa_attention_ref(bh(q), bh(kx), bh(vx), window=window)
    ref = ref.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_swa_softcap():
    key = jax.random.PRNGKey(9)
    b, s, h, d = 1, 64, 2, 32
    q = jax.random.normal(key, (b, s, h, d)) * 3
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d)) * 3
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    out = swa_attention(q, k, v, window=64, softcap=30.0, block=32,
                        interpret=True)

    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    ref = swa_attention_ref(bh(q), bh(k), bh(v), window=64, softcap=30.0)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# ssd (mamba-2 intra-chunk)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("l,chunk,h,p,n", [
    (64, 32, 4, 16, 32),
    (128, 32, 8, 32, 64),
    (32, 32, 2, 16, 16),     # single chunk
])
def test_ssd_intra_chunk_vs_ref(l, chunk, h, p, n):
    from repro.kernels.ssd import ssd_intra_chunk
    from repro.kernels.ssd.ref import ssd_intra_chunk_ref

    key = jax.random.PRNGKey(l + h)
    bb = 2
    x = jax.random.normal(key, (bb, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (bb, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.5)
    B = jax.random.normal(jax.random.fold_in(key, 3), (bb, l, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (bb, l, n))

    y, states, cum = ssd_intra_chunk(x, dt, A, B, C, chunk=chunk,
                                     interpret=True)
    nc = l // chunk if l > chunk and l % chunk == 0 else 1
    q = l // nc
    xr = x.reshape(bb, nc, q, h, p)
    dtr = dt.reshape(bb, nc, q, h)
    br = B.reshape(bb, nc, q, n)
    cr = C.reshape(bb, nc, q, n)
    y_ref, st_ref = ssd_intra_chunk_ref(xr, dtr, cum, br, cr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(states), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_composes_to_full_scan():
    """Kernel intra + JAX inter-chunk scan == ssd_chunked end-to-end."""
    from repro.kernels.ssd import ssd_intra_chunk
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(11)
    bb, l, h, p, n, chunk = 1, 64, 4, 16, 32, 32
    x = jax.random.normal(key, (bb, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (bb, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.5)
    B = jax.random.normal(jax.random.fold_in(key, 3), (bb, l, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (bb, l, n))
    D = jnp.zeros((h,))

    y_intra, states, cum = ssd_intra_chunk(x, dt, A, B, C, chunk=chunk,
                                           interpret=True)
    nc = l // chunk
    # inter-chunk recurrence (as in repro.models.ssm, g=1)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (bb,nc,h)

    def body(carry, inp):
        s_c, dec_c = inp
        return carry * dec_c[..., None, None] + s_c, carry

    init = jnp.zeros((bb, h, n, p))
    _, prev = jax.lax.scan(body, init,
                           (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev = prev.swapaxes(0, 1)                                # (bb,nc,h,n,p)
    inner = jnp.exp(cum)                                      # (bb,nc,q,h)
    cr = C.reshape(bb, nc, chunk, n)
    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp", cr, inner, prev)
    y = (y_intra + y_inter).reshape(bb, l, h, p)

    ref4 = ssd_chunked(x, dt, A,
                       B.reshape(bb, l, 1, n), C.reshape(bb, l, 1, n),
                       D, chunk=chunk)[0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref4),
                               rtol=1e-4, atol=1e-4)
