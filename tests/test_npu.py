"""Differential parity harness for the systolic-array NPU backend.

The NPU earns its place next to NVDLA only if every path is provably
exact: segment replay bit-identical to a naive per-access scan across a
(rows, cols, buffers) config grid, tiled-GEMM segment expansion covering
exactly the operand footprint (no gaps, no double counts beyond the
schedule's declared re-stream passes), and hypothesis-checked compiler
invariants — counting (hits <= accesses, row hits <= misses), 40-bit
address-overflow rejection, and tiling invariance (traffic and cycle
totals independent of tile-visit order for weight-stationary schedules).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import npu, traces
from repro.core.accelerator import MemSystemConfig
from repro.core.cache import LLCConfig, simulate_segments, simulate_trace
from repro.core.traces import BURST_BYTES

# the (rows, cols, ifm, wgt, acc) config grid: square/rectangular PE
# arrays, buffers from starved (forcing re-stream passes) to roomy
CONFIG_GRID = [
    npu.NPUConfig(rows=4, cols=4, ifm_buf_bytes=256, wgt_buf_bytes=128,
                  acc_buf_bytes=256),
    npu.NPUConfig(rows=4, cols=8, ifm_buf_bytes=128, wgt_buf_bytes=64,
                  acc_buf_bytes=128),
    npu.NPUConfig(rows=8, cols=4, ifm_buf_bytes=1024, wgt_buf_bytes=4096,
                  acc_buf_bytes=512),
    npu.NPUConfig(rows=16, cols=16, ifm_buf_bytes=4096, wgt_buf_bytes=512,
                  acc_buf_bytes=2048),
]
OPS = [
    npu.GemmOp("square", m=12, k=12, n=12),
    npu.GemmOp("ragged", m=10, k=9, n=7),
    npu.GemmOp("tall", m=37, k=5, n=3),
    npu.GemmOp("wide", m=3, k=6, n=41),
]
LLC_SMALL = LLCConfig(size_bytes=4096, ways=4, block_bytes=32)


def _scan_reference(segs, llc):
    """The naive per-access reference: expand every segment to byte
    addresses and replay them one at a time through the serial LRU."""
    blocks = (traces.expand(segs) // llc.block_bytes).astype(np.int32)
    hits = simulate_trace(blocks, sets=llc.sets, ways=llc.ways)
    return int(hits.sum()), int(len(blocks))


def _stream_bursts(segs, stream):
    return sum(s.count for s in segs if s.stream == stream)


def _burst_set(segs, stream):
    out = set()
    for s in segs:
        if s.stream == stream:
            out.update(range(s.base, s.base + s.count * s.stride, s.stride))
    return out


class TestSegmentParity:
    @pytest.mark.parametrize("cfg", CONFIG_GRID,
                             ids=lambda c: f"{c.rows}x{c.cols}")
    @pytest.mark.parametrize("op", OPS, ids=lambda o: o.name)
    def test_replay_bit_identical_to_per_access_scan(self, cfg, op):
        segs = npu.op_segments(op, cfg, 0, 1 << 20, 2 << 20)
        res = simulate_segments(segs, LLC_SMALL)
        ref_hits, ref_accesses = _scan_reference(segs, LLC_SMALL)
        assert (res.hits, res.accesses) == (ref_hits, ref_accesses)

    @pytest.mark.parametrize("cfg", CONFIG_GRID[:2],
                             ids=lambda c: f"{c.rows}x{c.cols}")
    def test_interleaved_workload_parity(self, cfg):
        ops = [npu.GemmOp("a", 9, 8, 7), npu.GemmOp("b", 7, 7, 9)]
        chunks = npu.npu_chunks(ops, cfg, chunk_bursts=4)
        res = simulate_segments(chunks, LLC_SMALL)
        assert (res.hits, res.accesses) == _scan_reference(chunks, LLC_SMALL)

    def test_window_is_exact_prefix(self):
        cfg = CONFIG_GRID[0]
        ops = [npu.GemmOp("a", 9, 8, 7), npu.GemmOp("b", 7, 7, 9)]
        full = traces.expand(npu.npu_chunks(ops, cfg, chunk_bursts=4))
        assert len(full) > 15
        win = traces.expand(npu.npu_chunks(ops, cfg, chunk_bursts=4,
                                           max_bursts=15))
        assert len(win) == 15
        np.testing.assert_array_equal(win, full[:15])


class TestFootprintCoverage:
    @pytest.mark.parametrize("cfg", CONFIG_GRID,
                             ids=lambda c: f"{c.rows}x{c.cols}")
    @pytest.mark.parametrize("op", OPS, ids=lambda o: o.name)
    def test_streams_cover_exact_operand_footprint(self, cfg, op):
        """Every stream's unique bursts tile [base, base + footprint)
        exactly — no gaps, no bursts outside the packed layout — and
        total bursts match the schedule's declared traffic (i.e. double
        reads happen only as declared re-stream passes)."""
        s = npu.schedule(op, cfg)
        bases = {"weight": 0, "ifmap": 1 << 20, "ofmap": 2 << 20}
        segs = npu.op_segments(op, cfg, *bases.values())
        for stream, footprint, traffic in (
                ("weight", s.weight_footprint, s.weight_traffic),
                ("ifmap", s.ifmap_footprint, s.ifmap_traffic),
                ("ofmap", s.ofmap_footprint, s.ofmap_traffic)):
            uniq = _burst_set(segs, stream)
            base = bases[stream]
            expect = set(range(base, base + footprint, BURST_BYTES))
            assert uniq == expect, f"{stream} coverage has gaps/strays"
            assert _stream_bursts(segs, stream) * BURST_BYTES == traffic

    def test_footprint_padding_is_burst_granular(self):
        """Packed footprints only ever exceed the raw operand bytes by
        per-tile burst alignment."""
        cfg, op = CONFIG_GRID[1], OPS[1]
        s = npu.schedule(op, cfg)
        raw_w = op.k * op.n * cfg.elem_bytes
        assert raw_w <= s.weight_footprint \
            < raw_w + s.n_k * s.n_n * BURST_BYTES

    def test_restreaming_multiplies_weight_traffic(self):
        """A stripe that outgrows the weight SRAM re-streams once per
        M block — the NVDLA weight_passes analogy."""
        cfg = npu.NPUConfig(rows=4, cols=4, ifm_buf_bytes=64,
                            wgt_buf_bytes=64, acc_buf_bytes=64)
        op = npu.GemmOp("restream", m=12, k=64, n=4)
        s = npu.schedule(op, cfg)
        assert s.n_m > 1 and s.weight_passes == (s.n_m,)
        segs = npu.op_segments(op, cfg, 0, 1 << 20, 2 << 20)
        assert (_stream_bursts(segs, "weight") * BURST_BYTES
                == s.weight_footprint * s.n_m)


class TestVisitOrderInvariance:
    def test_traffic_and_cycles_order_invariant(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=40, deadline=None)
        @given(m=st.integers(1, 24), k=st.integers(1, 24),
               n=st.integers(1, 24),
               rows=st.sampled_from([2, 4, 8]),
               cols=st.sampled_from([2, 4, 8]),
               wgt=st.sampled_from([32, 128, 4096]),
               acc=st.sampled_from([32, 256]),
               seed=st.integers(0, 2**31 - 1))
        def prop(m, k, n, rows, cols, wgt, acc, seed):
            cfg = npu.NPUConfig(rows=rows, cols=cols, ifm_buf_bytes=256,
                                wgt_buf_bytes=wgt, acc_buf_bytes=acc)
            op = npu.GemmOp("p", m=m, k=k, n=n)
            s = npu.schedule(op, cfg)
            perm = s.visits("nm")
            np.random.RandomState(seed).shuffle(perm)
            ref = npu.op_segments(op, cfg, 0, 1 << 20, 2 << 20, order="nm")
            for order in ("mn", perm):
                got = npu.op_segments(op, cfg, 0, 1 << 20, 2 << 20,
                                      order=order)
                for stream in ("weight", "ifmap", "ofmap"):
                    assert (_stream_bursts(got, stream)
                            == _stream_bursts(ref, stream))
                    assert _burst_set(got, stream) == _burst_set(ref, stream)
            # compute cycles are a sum over the tile set: replaying the
            # permuted visit order tile by tile reproduces the closed
            # form
            explicit = sum(
                s.m_szs[mi] + s.k_szs[ki] + s.n_szs[ni]
                + cfg.tile_overhead_cycles
                for ni, mi in perm for ki in range(s.n_k))
            assert explicit == s.compute_cycles

        prop()

    def test_non_permutation_order_rejected(self):
        op, cfg = OPS[0], CONFIG_GRID[0]
        s = npu.schedule(op, cfg)
        bad = s.visits("nm")[:-1]
        with pytest.raises(ValueError, match="permutation"):
            npu.op_segments(op, cfg, 0, 1 << 20, 2 << 20, order=bad)


class TestCompilerProperties:
    def test_counting_invariants_through_the_lane_engine(self):
        """The full sweep lane on an NPU trace obeys the same counting
        laws as NVDLA lanes: hits <= accesses, DRAM row hits <= misses,
        and the accelerator-stream counters are a subset of the lane."""
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        from repro.core.sweep import interference_lane_metrics

        @settings(max_examples=30, deadline=None)
        @given(m=st.integers(1, 20), k=st.integers(1, 20),
               n=st.integers(1, 20),
               grid=st.sampled_from([(2, 2), (4, 8), (8, 4)]))
        def prop(m, k, n, grid):
            cfg = npu.NPUConfig(rows=grid[0], cols=grid[1],
                                ifm_buf_bytes=128, wgt_buf_bytes=128,
                                acc_buf_bytes=128)
            trace = npu.npu_chunks([npu.GemmOp("p", m, k, n)], cfg,
                                   chunk_bursts=4)
            res = interference_lane_metrics(trace, llc=LLC_SMALL)
            assert 0 <= res.llc_hits <= res.accesses
            assert res.dram_row_hits <= res.accesses - res.llc_hits
            assert res.nvdla_accesses == sum(s.count for s in trace)
            assert res.nvdla_hits <= res.llc_hits

        prop()

    def test_40bit_overflow_rejected(self):
        op, cfg = OPS[0], CONFIG_GRID[0]
        with pytest.raises(ValueError, match="40-bit"):
            npu.op_segments(op, cfg, (1 << 40) - BURST_BYTES,
                            1 << 20, 2 << 20)

    def test_weight_heap_budget_rejected(self):
        huge = npu.GemmOp("huge", m=1, k=1 << 15, n=1 << 15)  # 1 GiB
        with pytest.raises(ValueError, match="weight heap"):
            npu.workload_op_segments([huge], npu.NPUConfig())

    def test_fmap_region_overrun_rejected(self):
        wide = npu.GemmOp("wide", m=1 << 14, k=1, n=1 << 14)  # 256 MiB out
        with pytest.raises(ValueError, match="fmap region"):
            npu.workload_op_segments([wide], npu.NPUConfig())

    def test_bad_config_and_op_rejected(self):
        with pytest.raises(ValueError, match="positive"):
            npu.NPUConfig(rows=0)
        with pytest.raises(ValueError, match="positive"):
            npu.GemmOp("bad", m=1, k=0, n=1)
        with pytest.raises(ValueError, match="unknown NPU workload"):
            npu.workload("resnet99")


class TestTiming:
    def test_simulated_rates_match_manual_fold(self):
        cfg = CONFIG_GRID[0]
        ops = [npu.GemmOp("a", 9, 8, 7), npu.GemmOp("b", 7, 7, 9)]
        mem = MemSystemConfig(llc=LLC_SMALL)
        rates = npu.op_stream_hit_rates(ops, cfg, mem)
        assert len(rates) == 2
        per_op = npu.workload_op_segments(ops, cfg)
        flat = [s for segs in per_op for s in segs]
        res = simulate_segments(flat, LLC_SMALL, per_segment=True)
        k = 0
        for segs, op_rates in zip(per_op, rates):
            tot = {"weight": [0, 0], "ifmap": [0, 0], "ofmap": [0, 0]}
            for s in segs:
                tot[s.stream][0] += int(res.per_segment_hits[k])
                tot[s.stream][1] += s.count
                k += 1
            for (h, a), r in zip(
                    (tot["weight"], tot["ifmap"], tot["ofmap"]), op_rates):
                assert 0.0 <= r <= 1.0
                assert r == pytest.approx(h / a if a else 0.0)

    def test_simulated_mode_bounded_by_perfect_and_coldest(self):
        cfg = CONFIG_GRID[0]
        ops = [npu.GemmOp("a", 16, 16, 16)]
        mem = MemSystemConfig(llc=LLC_SMALL)
        sim = npu.npu_time_s(ops, npu=cfg, mem=mem, mode="simulated")
        hot = npu.npu_time_s(ops, npu=cfg, mem=mem,
                             hit_rates=[(1.0, 1.0, 1.0)])
        cold = npu.npu_time_s(ops, npu=cfg, mem=mem,
                              hit_rates=[(0.0, 0.0, 0.0)])
        assert hot["cycles"] <= sim["cycles"] <= cold["cycles"]
        assert sim["mode"] == "simulated"

    def test_utilization_bounded_and_overheads_count(self):
        cfg = npu.NPUConfig()
        res = npu.op_cycles(npu.GemmOp("g", 512, 512, 512), cfg,
                            MemSystemConfig())
        assert 0.0 < res["utilization"] <= 1.0
        assert res["total"] >= res["compute"] >= 512  # M cycles minimum

    def test_mode_and_hit_rate_validation(self):
        ops = [npu.GemmOp("a", 4, 4, 4)]
        with pytest.raises(ValueError, match="unknown mode"):
            npu.npu_time_s(ops, mode="oracle")
        with pytest.raises(ValueError, match="must cover every op"):
            npu.npu_time_s(ops, hit_rates=[])


class TestZooWorkloads:
    @pytest.mark.parametrize("name", sorted(npu.WORKLOADS))
    def test_workloads_build_and_window(self, name):
        ops = npu.workload(name)
        assert len(ops) > 0 and all(o.macs > 0 for o in ops)
        win = npu.default_npu_window(name, max_bursts=128)
        assert sum(s.count for s in win) == 128
        # every address fits the lane engine's int32 metadata
        assert all(s.base + s.stride * s.count < 2**31 for s in win)

    def test_yolov3_gemms_match_conv_layers(self):
        from repro.core import yolov3

        convs = [la for la in yolov3.LAYERS if la.kind == "conv"]
        gemms = npu.yolov3_gemms()
        assert len(gemms) == len(convs)
        for la, g in zip(convs, gemms):
            assert (g.m, g.k, g.n) == (la.out_h * la.out_w,
                                       la.cin * la.ksize ** 2, la.cout)
            assert g.macs == la.macs


class TestDecodeWeightStream:
    def test_single_pass_covers_exactly_the_heap(self):
        cfg = npu.NPUConfig()
        segs = npu.decode_weight_segments(1 << 20, cfg, m=8)
        assert all(s.stream == "weight" for s in segs)
        total = sum(s.count for s in segs) * BURST_BYTES
        assert (1 << 20) <= total < (1 << 20) + (1 << 16)  # pad only
        uniq = _burst_set(segs, "weight")
        assert len(uniq) * BURST_BYTES == total  # single pass: no rereads

    def test_wide_batch_with_starved_sram_restreams(self):
        cfg = npu.NPUConfig(rows=8, cols=8, wgt_buf_bytes=1024,
                            acc_buf_bytes=64, ifm_buf_bytes=64)
        one = npu.decode_weight_segments(1 << 16, cfg, m=1)
        wide = npu.decode_weight_segments(1 << 16, cfg, m=64)
        assert (sum(s.count for s in wide)
                > sum(s.count for s in one))  # re-stream passes appeared


class TestServingOracle:
    def _ws(self):
        from repro.configs import get_smoke_config
        from repro.models import decode_working_set

        return decode_working_set(get_smoke_config("qwen2-0.5b"))

    def _kv(self, ws):
        from repro.serve.kvcache import PagedKVCache

        kv = PagedKVCache(num_blocks=32, block_size=16,
                          token_bytes=max(1, ws.kv_token_bytes))
        kv.admit(0, prompt_tokens=8, max_new=8)
        kv.admit(1, prompt_tokens=8, max_new=8)
        return kv

    def test_npu_backend_prices_steps(self):
        from repro.serve.oracle import SoCLatencyOracle

        ws = self._ws()
        kv = self._kv(ws)
        o = SoCLatencyOracle(ws, llc=LLCConfig(), weight_bytes=1 << 20,
                             backend="npu")
        lat = o.decode_step(kv, [0, 1])
        assert lat.cycles > 0 and lat.seconds > 0
        assert o.decode_step(kv, [0, 1]) is lat  # memoized

    def test_contiguous_single_pass_matches_nvdla_stream(self):
        """With roomy SRAMs the NPU fetches its stripes once, in order,
        contiguously — the burst stream degenerates to NVDLA's
        sequential read, so the simulated step cost is identical (the
        cross-backend differential anchor)."""
        from repro.serve.oracle import SoCLatencyOracle

        ws = self._ws()
        kv = self._kv(ws)
        lat = {}
        for backend in ("nvdla", "npu"):
            o = SoCLatencyOracle(ws, llc=LLCConfig(), weight_bytes=1 << 20,
                                 backend=backend)
            lat[backend] = o.decode_step(kv, [0, 1]).cycles
        assert lat["nvdla"] == lat["npu"]

    def test_backend_validation(self):
        from repro.serve.oracle import SoCLatencyOracle

        ws = self._ws()
        with pytest.raises(ValueError, match="unknown backend"):
            SoCLatencyOracle(ws, weight_bytes=1 << 20, backend="tpu")
        with pytest.raises(ValueError, match="only applies"):
            SoCLatencyOracle(ws, weight_bytes=1 << 20,
                             npu=npu.NPUConfig())
