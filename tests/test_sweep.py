"""Batched sweep engine + early-exit FAME-1 scheduler.

Parity requirements (no Hypothesis — these must run everywhere):
* vmapped padded-geometry simulation == per-config unbatched scans,
  bit for bit;
* early-exit chunked FAME-1 replay == the seed's fixed schedule,
  bit for bit, with and without stalls (including all-stall cycles
  that pre-compaction drops);
* sweep drivers keep the paper-anchored closed-form grids intact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traces
from repro.core.cache import LLCConfig, simulate_trace
from repro.core.fame1 import Component, FAME1Pipeline
from repro.core.socsim import simulate_dbb_stream
from repro.core.sweep import (
    LaneMetrics,
    MixConfig,
    SweepGrid,
    batched_hits,
    batched_hit_rates,
    corunner_segments,
    grid_configs,
    interference_lane_metrics,
    interference_lane_metrics_batch,
    step_lane_metrics,
    segment_lane_hit_counts,
    segment_lane_hit_rates,
    segment_sweep_hit_rates,
    sweep_interference,
    sweep_llc,
)

# the expanded-trace lanes stay in service as parity oracles — their
# deprecation warning is expected here (and asserted explicitly below)
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

LLC = LLCConfig(size_bytes=4096, ways=4, block_bytes=64)


def _window(n=768):
    return traces.expand(traces.default_dbb_window(max_bursts=n))


# --------------------------------------------------------------------------
# vmapped sweeps
# --------------------------------------------------------------------------
def test_batched_hits_bitwise_parity_with_per_config_loop():
    addrs = _window()
    configs = list(grid_configs((0.5, 8, 64), (32, 64, 128)).values())
    got = np.asarray(batched_hits(addrs, configs))
    for i, c in enumerate(configs):
        blocks = jnp.asarray((addrs // c.block_bytes).astype(np.int32))
        ref = np.asarray(simulate_trace(blocks, sets=c.sets, ways=c.ways))
        np.testing.assert_array_equal(got[i], ref, err_msg=str(c))


def test_batched_hit_rates_block_size_ordering():
    addrs = _window()
    configs = [LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=b)
               for b in (32, 64, 128)]
    r32, r64, r128 = np.asarray(batched_hit_rates(addrs, configs))
    assert r32 < r64 < r128, "spatial locality must grow with block size"


def test_segment_sweep_matches_expanded_scans():
    segs = traces.window(traces.network_trace(max_ops=3), 30_000)
    addrs = traces.expand(segs)
    configs = list(grid_configs((0.5, 64), (32, 128)).values())
    got = segment_sweep_hit_rates(segs, configs)
    for i, c in enumerate(configs):
        blocks = jnp.asarray((addrs // c.block_bytes).astype(np.int32))
        ref = float(jnp.mean(simulate_trace(
            blocks, sets=c.sets, ways=c.ways).astype(jnp.float32)))
        assert abs(got[i] - ref) < 1e-6, c


# --------------------------------------------------------------------------
# segment-lane engine (traced geometry)
# --------------------------------------------------------------------------
def test_segment_lanes_bitwise_parity_every_grid_geometry():
    """The satellite parity requirement: segment-lane sweep hit rates
    equal ``batched_hit_rates`` on the expanded trace for every grid
    geometry — counts bit-identical, not approximately."""
    segs = traces.window(traces.network_trace(max_ops=4), 25_000)
    addrs = traces.expand(segs)
    configs = list(grid_configs((0.5, 8, 64, 1024),
                                (32, 64, 128, 256)).values())
    counts = segment_lane_hit_counts(segs, configs)
    bits = np.asarray(batched_hits(addrs, configs))
    np.testing.assert_array_equal(counts.sum(axis=1), bits.sum(axis=1))
    rates = segment_lane_hit_rates(segs, configs)
    np.testing.assert_allclose(
        rates, bits.mean(axis=1, dtype=np.float64), atol=0)


def test_segment_lanes_per_segment_attribution():
    segs = [traces.Segment(0, 32, 3000), traces.Segment(0, 32, 500),
            traces.Segment(1 << 18, 32, 64)]
    addrs = traces.expand(segs)
    configs = [LLCConfig(4096, 4, 64), LLCConfig(64 * 1024, 8, 128)]
    counts = segment_lane_hit_counts(segs, configs)
    for i, c in enumerate(configs):
        blocks = jnp.asarray((addrs // c.block_bytes).astype(np.int32))
        bits = np.asarray(simulate_trace(blocks, sets=c.sets, ways=c.ways))
        o, ref = 0, []
        for s in segs:
            ref.append(int(bits[o:o + s.count].sum()))
            o += s.count
        assert counts[i].tolist() == ref


def test_segment_lanes_per_lane_traces():
    """Fig. 6 shape: one geometry, per-lane traces padded to the
    longest lane with no-op segments."""
    llc = LLCConfig(64 * 1024, 8, 64)
    nv = traces.default_dbb_window(max_bursts=768)
    lanes, refs = [], []
    for n in (0, 2):
        segs, _ = corunner_segments(nv, llc=llc, mix=MixConfig(n, "dram"),
                                    chunk_bursts=16)
        lanes.append(segs)
        blocks = (traces.expand(segs) // llc.block_bytes).astype(np.int32)
        refs.append(int(np.asarray(simulate_trace(
            jnp.asarray(blocks), sets=llc.sets, ways=llc.ways)).sum()))
    counts = segment_lane_hit_counts(lanes, [llc, llc])
    assert counts.sum(axis=1).tolist() == refs


def test_segment_lanes_rejects_sparse_strides():
    with np.testing.assert_raises(ValueError):
        segment_lane_hit_counts([traces.Segment(0, 256, 100)],
                                [LLCConfig(4096, 4, 64)])


def test_sweep_llc_full_trace_mode():
    """window_bursts=None runs the whole-network compressed trace."""
    sw = sweep_llc(sizes_kib=(8,), blocks=(64,), window_bursts=None)
    frame_bursts = traces.total_bursts(traces.network_trace())
    assert sw.window_bursts == frame_bursts
    (rate,) = sw.sim_hit_rates.values()
    assert 0.0 < rate < 1.0


def test_sweep_llc_keeps_closed_form_grid_and_adds_sim():
    from repro.core.soc import llc_sweep

    sizes, blocks = (0.5, 1024), (32, 64)
    sw = sweep_llc(sizes_kib=sizes, blocks=blocks, window_bursts=512)
    ref = llc_sweep(sizes_kib=sizes, blocks=blocks)
    assert sw.kind == "llc"
    assert sw.no_llc_s == ref["no_llc_s"]
    assert sw.speedups == ref["grid"]
    assert set(sw.sim_hit_rates) == set(ref["grid"])
    assert all(0.0 <= v <= 1.0 for v in sw.sim_hit_rates.values())


def test_sweep_interference_keeps_closed_form_and_degrades_rows():
    sw = sweep_interference(corunners=(0, 4), window_bursts=1024)
    assert sw.kind == "interference"
    assert all(abs(v - 1.0) < 1e-9 for v in sw.slowdowns["l1"].values())
    assert sw.slowdowns["dram"][4] > sw.slowdowns["llc"][4] > 1.0
    # simulated DRAM row locality: untouched by L1-fitting co-runners,
    # degraded by DRAM-fitting ones
    rh = sw.sim_row_hit_rates
    assert rh[("l1", 4)] == rh[("l1", 0)]
    assert rh[("dram", 4)] < rh[("dram", 0)]


# --------------------------------------------------------------------------
# typed sweep-result API + batched lane programs
# --------------------------------------------------------------------------
def test_expanded_trace_lanes_emit_deprecation_warning():
    addrs = _window(256)
    configs = [LLC]
    with pytest.warns(DeprecationWarning, match="expanded-trace"):
        batched_hits(addrs, configs)
    with pytest.warns(DeprecationWarning, match="expanded-trace"):
        batched_hit_rates(addrs, configs)


def test_lane_metrics_record_round_trip():
    nv = traces.default_dbb_window(max_bursts=512)
    from repro.core.dram import DRAMConfig

    m = interference_lane_metrics(nv, llc=LLC, dram=DRAMConfig(),
                                  mix=MixConfig(2, "llc"))
    rec = m.to_record()
    assert isinstance(rec, dict) and set(rec) == set(
        LaneMetrics._INT_FIELDS) | set(LaneMetrics._FLOAT_FIELDS)
    # json round-trip (what the campaign journal does) is lossless
    import json

    back = LaneMetrics.from_record(json.loads(json.dumps(rec)))
    assert back == m
    for f in LaneMetrics._INT_FIELDS:
        assert isinstance(getattr(back, f), int), f
    for f in LaneMetrics._FLOAT_FIELDS:
        assert isinstance(getattr(back, f), float), f
    with pytest.raises(KeyError):
        LaneMetrics.from_record({k: v for k, v in rec.items()
                                 if k != "accesses"})


def test_sweep_grid_record_round_trip():
    import json

    sw = sweep_interference(corunners=(0, 2), window_bursts=256)
    back = SweepGrid.from_record(json.loads(json.dumps(sw.to_record())))
    assert back == sw
    sw = sweep_llc(sizes_kib=(8,), blocks=(64,), window_bursts=256)
    back = SweepGrid.from_record(json.loads(json.dumps(sw.to_record())))
    assert back == sw


def test_batched_lane_metrics_bit_identical_to_sequential():
    """The tentpole parity requirement: one vmapped lane program over a
    mixed bucket of geometries/mixes/DRAM specs returns *exactly* the
    LaneMetrics the sequential engine computes, field for field."""
    from repro.core.dram import DRAMConfig

    nv = traces.default_dbb_window(max_bursts=512)
    llcs, drams, mixes = [], [], []
    for w in (1, 2, 4, 8):
        for mix in (MixConfig(0, "l1"), MixConfig(2, "llc"),
                    MixConfig(3, "dram")):
            llcs.append(LLCConfig(64 * 64 * w, w, 64))
            drams.append(DRAMConfig())
            mixes.append(mix)
    # a second bucket: different sets count + non-default DRAM timing
    llcs.append(LLCConfig(128 * 64 * 2, 2, 64))
    drams.append(DRAMConfig(banks=16, row_bytes=1024))
    mixes.append(MixConfig(1, "llc"))
    batch = interference_lane_metrics_batch(nv, llcs=llcs, drams=drams,
                                            mixes=mixes)
    for i, (llc, dram, mix) in enumerate(zip(llcs, drams, mixes)):
        ref = interference_lane_metrics(nv, llc=llc, dram=dram, mix=mix)
        assert batch[i] == ref, f"lane {i}: {llc} {mix}"


def test_corunner_meta_matches_corunner_segments():
    """The array-native trace builder must emit the same interleaved
    lane, segment for segment, as the Segment-object builder — across
    wss classes, co-runner counts, chunk sizes, and spans small enough
    to hit the multi-wrap fallback."""
    from repro.core.sweep import corunner_meta
    from repro.core.traces import segment_tuple

    nv = traces.default_dbb_window(max_bursts=256)
    for size in (512, 2048, 65536):
        for mix in (MixConfig(0, "l1"), MixConfig(1, "llc"),
                    MixConfig(3, "llc"), MixConfig(2, "dram")):
            for chunk in (4, 16, 33):
                llc = LLCConfig(size, 2, 64)
                segs, nv_ref = corunner_segments(nv, llc=llc, mix=mix,
                                                 chunk_bursts=chunk)
                ref = np.asarray([segment_tuple(s) for s in segs],
                                 np.int64).reshape(-1, 3)
                b, s, c, m = corunner_meta(nv, llc=llc, mix=mix,
                                           chunk_bursts=chunk)
                label = f"size={size} mix={mix} chunk={chunk}"
                np.testing.assert_array_equal(b, ref[:, 0], err_msg=label)
                np.testing.assert_array_equal(s, ref[:, 1], err_msg=label)
                np.testing.assert_array_equal(c, ref[:, 2], err_msg=label)
                np.testing.assert_array_equal(
                    m, np.asarray(nv_ref, bool), err_msg=label)


def test_batched_lane_metrics_empty_and_length_checks():
    assert interference_lane_metrics_batch(
        traces.default_dbb_window(max_bursts=64),
        llcs=[], drams=[], mixes=[]) == []
    from repro.core.dram import DRAMConfig

    with pytest.raises(ValueError):
        interference_lane_metrics_batch(
            traces.default_dbb_window(max_bursts=64),
            llcs=[LLC], drams=[DRAMConfig(), DRAMConfig()],
            mixes=[MixConfig()])


# --------------------------------------------------------------------------
# early-exit FAME-1 scheduler
# --------------------------------------------------------------------------
def _pipeline():
    accel = Component("nvdla", lambda s, x: (s + 1, x * 2.0),
                      jnp.int32(0), jnp.float32(0.0))
    mem = Component("memmodel", lambda s, x: (s + x, x + s),
                    jnp.float32(0.0), jnp.float32(0.0))
    return FAME1Pipeline([accel, mem])


def test_early_exit_equals_fixed_schedule_no_stalls():
    tokens = jnp.arange(1.0, 33.0)
    pipe = _pipeline()
    s_ref, out_ref, n_ref = pipe.run(tokens, early_exit=False)
    fixed_cycles = pipe.last_host_cycles
    s_fast, out_fast, n_fast = pipe.run(tokens, early_exit=True)
    assert int(n_ref) == int(n_fast) == 32
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_fast))
    np.testing.assert_array_equal(np.asarray(s_ref[0]), np.asarray(s_fast[0]))
    np.testing.assert_array_equal(np.asarray(s_ref[1]), np.asarray(s_fast[1]))
    assert pipe.last_host_cycles < fixed_cycles / 3, \
        "early exit must skip most of the 4*T*(n+1) schedule"


def test_early_exit_equals_fixed_under_random_stalls():
    tokens = jnp.arange(1.0, 17.0)
    pipe = _pipeline()
    for seed in range(6):
        stalls = jax.random.bernoulli(
            jax.random.PRNGKey(seed), 0.45, (16 * 8, 2))
        _, out_ref, n_ref = pipe.run(tokens, host_stalls=stalls,
                                     early_exit=False)
        _, out_fast, n_fast = pipe.run(tokens, host_stalls=stalls,
                                       early_exit=True)
        assert int(n_ref) == int(n_fast)
        np.testing.assert_array_equal(np.asarray(out_ref),
                                      np.asarray(out_fast))


def test_all_stall_cycles_are_compacted_away():
    tokens = jnp.arange(1.0, 9.0)
    pipe = _pipeline()
    h = 8 * 8
    # every other host cycle stalls *all* components
    stalls = jnp.zeros((h, 2), bool).at[::2].set(True)
    _, out_ref, n_ref = pipe.run(tokens, host_stalls=stalls,
                                 early_exit=False)
    _, out_fast, n_fast = pipe.run(tokens, host_stalls=stalls,
                                   early_exit=True)
    assert int(n_ref) == int(n_fast) == 8
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_fast))
    assert pipe.last_host_cycles <= h // 2, \
        "compaction must drop the all-stall cycles before simulating"


def test_dbb_stream_early_exit_parity_and_host_cycles():
    addrs = traces.expand(traces.default_dbb_window(max_bursts=96))
    ref = simulate_dbb_stream(addrs, llc=LLC, early_exit=False)
    fast = simulate_dbb_stream(addrs, llc=LLC, early_exit=True)
    np.testing.assert_array_equal(np.asarray(ref.latencies),
                                  np.asarray(fast.latencies))
    assert int(ref.total_cycles) == int(fast.total_cycles)
    assert fast.host_cycles < ref.host_cycles / 3


# --------------------------------------------------------------------------
# step_lane_metrics: the serving engine's step-latency entry point
# --------------------------------------------------------------------------
def test_step_lane_metrics_cold_is_interference_lane():
    segs = traces.default_dbb_window(max_bursts=512)
    from repro.core.dram import DRAMConfig

    dram = DRAMConfig()
    assert (step_lane_metrics(segs, llc=LLC, dram=dram)
            == interference_lane_metrics(segs, llc=LLC, dram=dram,
                                         mix=MixConfig()))


def test_step_lane_metrics_marginal_matches_warmed_pipeline():
    """The marginal claim, checked against an independent engine: the
    FAME-1 per-access pipeline run on the expanded prefix+step trace
    minus the same pipeline on the prefix alone."""
    from repro.core.dram import DRAMConfig

    dram = DRAMConfig()
    prefix = [traces.Segment(0, 32, 64, "w"),
              traces.Segment(1 << 20, 32, 48, "kv0")]
    step = [traces.Segment(0, 32, 64, "w"),
            traces.Segment(1 << 21, 32, 32, "kv1")]
    m = step_lane_metrics(step, llc=LLC, dram=dram, warm_prefix=prefix)
    full = simulate_dbb_stream(traces.expand(prefix + step), llc=LLC,
                               dram=dram)
    warm = simulate_dbb_stream(traces.expand(prefix), llc=LLC, dram=dram)
    assert m.total_cycles == int(full.total_cycles) - int(warm.total_cycles)
    assert m.accesses == sum(s.count for s in step)


def test_step_lane_metrics_steady_state_occupancy_effect():
    """A periodic working set that fits the LLC re-hits fully at steady
    state; adding a co-resident stream past capacity breaks the cyclic
    re-reference pattern (the serving-side Fig. 6 story)."""
    from repro.core.dram import DRAMConfig

    dram = DRAMConfig()
    fits = [traces.Segment(0, 32, 64, "w")]              # 2 KiB < 4 KiB LLC
    m1 = step_lane_metrics(fits, llc=LLC, dram=dram, warm_prefix=fits)
    assert m1.hit_rate == 1.0
    over = fits + [traces.Segment(1 << 20, 32, 96, "kv0")]   # 5 KiB > LLC
    m2 = step_lane_metrics(over, llc=LLC, dram=dram, warm_prefix=over)
    assert m2.hit_rate < m1.hit_rate
    assert m2.total_cycles > m1.total_cycles


def test_step_lane_metrics_marginal_satisfies_closed_form():
    """The counter-wise subtraction preserves the closed-form latency
    identity (it is linear in the counters) — the same invariant the
    campaign journal enforces on fresh records."""
    from repro.core.dram import DRAMConfig
    from repro.core.socsim import check_segment_totals

    dram = DRAMConfig()
    trace = traces.default_dbb_window(max_bursts=768)
    m = step_lane_metrics(trace, llc=LLC, dram=dram, warm_prefix=trace,
                          mix=MixConfig(2, "llc"))
    check_segment_totals(accesses=m.accesses, llc_hits=m.llc_hits,
                         dram_row_hits=m.dram_row_hits,
                         total_cycles=m.total_cycles, dram=dram,
                         t_llc_hit=m.t_llc_hit)


def test_deprecated_wrappers_attribute_warning_to_caller():
    """The one-release wrappers pass stacklevel=2, so the deprecation
    points at the calling file, not at sweep.py internals."""
    addrs = _window(128)
    with pytest.warns(DeprecationWarning) as rec:
        batched_hits(addrs, [LLC])
    assert any(w.filename == __file__ for w in rec)
    with pytest.warns(DeprecationWarning) as rec:
        batched_hit_rates(addrs, [LLC])
    assert any(w.filename == __file__ for w in rec)


def test_socsim_positional_configs_deprecated():
    """socsim entry points accept configs keyword-only; positional use
    warns for one release and double/missing configs raise."""
    from repro.core.dram import DRAMConfig
    from repro.core.socsim import simulate_dbb_segments

    segs = traces.default_dbb_window(max_bursts=128)
    addrs = traces.expand(segs)
    ref_seg = simulate_dbb_segments(segs, llc=LLC)
    with pytest.warns(DeprecationWarning, match="positional"):
        legacy = simulate_dbb_segments(segs, LLC)
    assert legacy.total_cycles == ref_seg.total_cycles
    ref_str = simulate_dbb_stream(addrs, llc=LLC)
    with pytest.warns(DeprecationWarning, match="positional"):
        legacy = simulate_dbb_stream(addrs, LLC, DRAMConfig())
    assert int(legacy.total_cycles) == int(ref_str.total_cycles)
    with pytest.raises(TypeError, match="missing required keyword"):
        simulate_dbb_segments(segs)
    with pytest.raises(TypeError, match="both positionally"):
        simulate_dbb_segments(segs, LLC, llc=LLC)
