"""Checkpoint store: atomicity, integrity, GC, async, elastic reshard."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(t, str(tmp_path), 5)
    out = restore(t, str(tmp_path), 5)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_ignores_torn(tmp_path):
    t = _tree()
    save(t, str(tmp_path), 1)
    save(t, str(tmp_path), 2)
    # simulate a crash mid-save of step 3: no COMMIT file
    os.makedirs(tmp_path / "step_000000003")
    assert latest_step(str(tmp_path)) == 2


def test_crc_detects_corruption(tmp_path):
    t = _tree()
    path = save(t, str(tmp_path), 1)
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr.flat[0] += 1.0
    np.save(leaf, arr)
    with pytest.raises(IOError):
        restore(t, str(tmp_path), 1)


def test_corrupt_manifest_raises_checkpoint_error(tmp_path):
    from repro.checkpoint import CheckpointCorruptError

    t = _tree()
    path = save(t, str(tmp_path), 1)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        f.write('{"treedef": "garb')        # torn mid-write
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        restore(t, str(tmp_path), 1)


def test_missing_leaf_raises_checkpoint_error(tmp_path):
    from repro.checkpoint import CheckpointCorruptError

    t = _tree()
    path = save(t, str(tmp_path), 1)
    os.remove(os.path.join(path, "leaf_00001.npy"))
    with pytest.raises(CheckpointCorruptError, match="leaf"):
        restore(t, str(tmp_path), 1)


def test_explicit_restore_of_torn_step_raises(tmp_path):
    from repro.checkpoint import CheckpointCorruptError

    t = _tree()
    save(t, str(tmp_path), 1)
    os.remove(os.path.join(tmp_path, "step_000000001", "COMMIT"))
    with pytest.raises(CheckpointCorruptError, match="COMMIT"):
        restore(t, str(tmp_path), 1)


def test_corrupt_error_is_oserror(tmp_path):
    """Existing callers guard restores with ``except OSError`` — the
    typed error must stay inside that hierarchy."""
    from repro.checkpoint import CheckpointCorruptError

    assert issubclass(CheckpointCorruptError, OSError)


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(t, s)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 4
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_000000003", "step_000000004"]


def test_elastic_reshard(tmp_path):
    """Restore places leaves onto an explicit (new) sharding — the elastic
    resume path: save on mesh A, restore onto mesh B."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    save(t, str(tmp_path), 1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = restore(t, str(tmp_path), 1, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


def test_loop_failure_injection_and_resume(tmp_path):
    from repro.configs import get_smoke_config
    from repro.train.loop import LoopConfig, train
    from repro.train.optim import AdamWConfig

    cfg = get_smoke_config("qwen2-0.5b")
    loop_cfg = LoopConfig(total_steps=12, checkpoint_every=4,
                          checkpoint_dir=str(tmp_path), async_save=False,
                          log_every=100)
    boom = {"armed": True}

    def failure_hook(step):
        if step == 6 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure at step 6")

    res = train(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, decay_steps=50),
                loop_cfg, global_batch=2, seq_len=16,
                failure_hook=failure_hook, log=lambda s: None)
    assert res.restarts == 1
    assert int(res.state.step) == 12
    # checkpointed resume happened from step 4, so steps 4..6 re-ran
    assert latest_step(str(tmp_path)) == 12
