"""Unit tests: HLO collective parser, roofline terms, optimizer schedule."""
from __future__ import annotations

import jax.numpy as jnp

from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.train.optim import AdamWConfig, lr_schedule

HLO = """
ENTRY %main {
  %ag = bf16[16,4096]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%p1), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%p2), replica_groups=[32,8]<=[256], dimensions={0}
  %cp = bf16[128,128]{1,0} collective-permute(%p3), source_target_pairs={{0,1}}
  %ags = (bf16[8,8]{1,0}, bf16[64,8]{1,0}) all-gather-start(%p4), replica_groups=[32,8]<=[256]
}
"""


def test_parse_collectives_counts_and_groups():
    st = parse_collectives(HLO, n_devices=256)
    assert st.counts == {"all-gather": 2, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1}
    assert st.by_group_size["all-gather/16"] == 1
    assert st.by_group_size["all-reduce/4"] == 1
    assert st.by_group_size["reduce-scatter/8"] == 1


def test_parse_collectives_wire_bytes():
    st = parse_collectives(HLO, n_devices=256)
    ag = 16 * 4096 * 2 * (15 / 16)              # result x (n-1)/n
    ar = 2 * 1024 * 4 * (3 / 4)
    rs = 64 * 4 * 7                              # shard result x (n-1)
    cp = 128 * 128 * 2
    ags = (8 * 8 + 64 * 8) * 2 // 2 * (7 / 8)    # tuple: half is the result
    assert abs(st.wire_bytes - (ag + ar + rs + cp + ags)) < 1.0


def test_roofline_terms_dominance():
    r = roofline_terms(flops=197e12, bytes_accessed=819e9 * 2,
                       wire_bytes=50e9 * 0.5, peak_flops=197e12,
                       hbm_bw=819e9, link_bw=50e9)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 2.0) < 1e-9
    assert r["dominant"] == "memory"
    assert abs(r["roofline_fraction"] - 0.5) < 1e-9


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100, 1000)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-9            # linear warmup
    assert abs(lrs[2] - 1e-3) < 1e-9            # peak at warmup end
    assert lrs[2] > lrs[3] > lrs[4]             # cosine decay
    assert abs(lrs[4] - 1e-4) < 1e-9            # floor = min_lr_ratio * lr
    assert abs(lrs[5] - 1e-4) < 1e-9            # clamped after decay_steps
