"""Campaign orchestrator: spec hashing, journaling, resume, guardrails,
and fault-injection equivalence (crash / hang / NaN / torn write)."""
from __future__ import annotations

import json
import os
import tempfile

import pytest

from repro.campaign import (
    CampaignSpec,
    FaultInjector,
    GeometrySpec,
    InjectedCrash,
    Journal,
    JournalError,
    MixSpec,
    ModelSpec,
    RetryPolicy,
    example_spec,
    plan_from_indices,
    run_campaign,
)
from repro.campaign.manifest import record_crc


def tiny_spec(points: int = 4) -> CampaignSpec:
    return example_spec(points=points, window_bursts=256)


def canon(manifest: dict) -> str:
    return json.dumps(manifest, sort_keys=True)


# --------------------------------------------------------------------------
# spec expansion and content hashing
# --------------------------------------------------------------------------
def test_expand_is_deterministic():
    spec = tiny_spec()
    a = [p.point_id for p in spec.expand()]
    b = [p.point_id for p in tiny_spec().expand()]
    assert a == b
    assert len(set(a)) == len(a)


def test_point_id_tracks_physics():
    g1, g2 = GeometrySpec(8, ways=2), GeometrySpec(16, ways=2)
    m, x = ModelSpec(window_bursts=64), MixSpec()
    from repro.campaign.spec import CampaignPoint, DRAMSpec

    p1 = CampaignPoint(m, g1, x, DRAMSpec())
    p2 = CampaignPoint(m, g2, x, DRAMSpec())
    assert p1.point_id != p2.point_id
    assert p1.point_id == CampaignPoint(m, g1, x, DRAMSpec()).point_id


def test_spec_round_trips_json(tmp_path):
    spec = tiny_spec()
    path = str(tmp_path / "spec.json")
    spec.save(path)
    again = CampaignSpec.load(path)
    assert again == spec
    assert again.spec_hash == spec.spec_hash


def test_spec_validation():
    with pytest.raises(ValueError, match="wss"):
        MixSpec(1, "l2")
    with pytest.raises(ValueError, match="model"):
        ModelSpec(name="resnet")
    with pytest.raises(ValueError, match="row_bytes"):
        CampaignSpec(name="bad", geometries=(GeometrySpec(8, block=96),))


# --------------------------------------------------------------------------
# clean run + resume
# --------------------------------------------------------------------------
def test_clean_run_writes_manifest(tmp_path):
    spec = tiny_spec()
    res = run_campaign(spec, str(tmp_path))
    assert res.completed == 4 and not res.failed
    m = json.load(open(res.manifest_path))
    assert m["spec_hash"] == spec.spec_hash
    assert [p["point_id"] for p in m["points"]] == \
        [p.point_id for p in spec.expand()]
    for p in m["points"]:
        r = p["result"]
        assert 0 <= r["llc_hits"] <= r["accesses"]
        assert r["dram_row_hits"] <= r["accesses"] - r["llc_hits"]


def test_resume_is_noop_after_success(tmp_path):
    spec = tiny_spec()
    first = run_campaign(spec, str(tmp_path))
    second = run_campaign(spec, str(tmp_path), resume=True)
    assert second.executed == 0 and second.resumed == 4
    assert canon(first.manifest) == canon(second.manifest)


def test_existing_journal_requires_resume_or_overwrite(tmp_path):
    spec = tiny_spec()
    run_campaign(spec, str(tmp_path))
    with pytest.raises(JournalError, match="resume"):
        run_campaign(spec, str(tmp_path))
    res = run_campaign(spec, str(tmp_path), overwrite=True)
    assert res.executed == 4


def test_resume_refuses_other_campaign(tmp_path):
    run_campaign(tiny_spec(), str(tmp_path))
    other = example_spec(points=2, window_bursts=128)
    with pytest.raises(JournalError, match="different campaign"):
        run_campaign(other, str(tmp_path), resume=True)


def test_torn_journal_tail_reruns_point(tmp_path):
    spec = tiny_spec()
    first = run_campaign(spec, str(tmp_path))
    journal = os.path.join(str(tmp_path), "journal.jsonl")
    lines = open(journal).read().splitlines(keepends=True)
    # tear into the final point record (drop the trailing "done" record
    # and half of the last point line) — the classic crash-mid-append
    with open(journal, "w") as f:
        f.writelines(lines[:-2])
        f.write(lines[-2][: len(lines[-2]) // 2])
    res = run_campaign(spec, str(tmp_path), resume=True)
    assert res.dropped_records == 1
    assert res.executed == 1 and res.resumed == 3
    assert canon(first.manifest) == canon(res.manifest)


def test_journal_crc_rejects_bitflips(tmp_path):
    spec = tiny_spec()
    run_campaign(spec, str(tmp_path))
    journal = Journal(os.path.join(str(tmp_path), "journal.jsonl"))
    records, dropped = journal.replay()
    assert dropped == 0
    # flip a digit inside a committed record's result
    text = open(journal.path).read()
    bad = text.replace('"accesses":256', '"accesses":999', 1)
    assert bad != text
    open(journal.path, "w").write(bad)
    _, dropped = journal.replay()
    assert dropped == 1


def test_record_crc_excludes_itself():
    rec = {"kind": "done", "completed": 1, "failed": 0}
    crc = record_crc(rec)
    assert record_crc({**rec, "crc": crc}) == crc


# --------------------------------------------------------------------------
# faults: retry, quarantine, equivalence
# --------------------------------------------------------------------------
def _run_until_done(spec, out_dir, plan, policy, **kw):
    """Drive a faulted campaign the way an operator would: rerun with
    --resume after every simulated process death."""
    runs = 0
    while True:
        runs += 1
        assert runs < 12, "campaign did not converge"
        hooks = FaultInjector(plan, out_dir)
        try:
            return run_campaign(spec, out_dir, resume=runs > 1,
                                policy=policy, hooks=hooks, **kw), runs
        except InjectedCrash:
            continue


def test_fault_equivalence_all_kinds(tmp_path):
    """A campaign surviving one crash, one hang, one NaN, and one torn
    write ends bit-identical to an uninterrupted campaign."""
    spec = tiny_spec()
    clean = run_campaign(spec, str(tmp_path / "clean"))
    plan = plan_from_indices(spec, [
        {"point": 0, "kind": "nan"},
        {"point": 1, "kind": "crash"},
        {"point": 2, "kind": "hang", "hang_s": 0.8},
        {"point": 3, "kind": "torn"},
    ])
    policy = RetryPolicy(max_retries=2, timeout_s=0.25, backoff_s=0.01)
    res, runs = _run_until_done(spec, str(tmp_path / "faulted"),
                                plan, policy)
    assert runs >= 3            # crash and torn each cost one process
    assert not res.failed
    assert canon(res.manifest) == canon(clean.manifest)


def test_nan_quarantined_without_retries(tmp_path):
    spec = tiny_spec()
    plan = plan_from_indices(spec, [{"point": 0, "kind": "nan"}])
    res = run_campaign(spec, str(tmp_path),
                       policy=RetryPolicy(max_retries=0, backoff_s=0),
                       hooks=FaultInjector(plan, str(tmp_path)))
    assert res.manifest["counts"] == {"total": 4, "completed": 3,
                                      "failed": 1}
    (info,) = res.failed.values()
    assert "finite" in info["error"]
    # resume keeps the quarantine; --retry-failed clears it
    keep = run_campaign(spec, str(tmp_path), resume=True,
                        hooks=FaultInjector(plan, str(tmp_path)))
    assert keep.executed == 0 and keep.manifest["counts"]["failed"] == 1
    heal = run_campaign(spec, str(tmp_path), resume=True, retry_failed=True,
                        hooks=FaultInjector(plan, str(tmp_path)))
    assert heal.completed == 4 and not heal.failed


def test_monotone_ways_guardrail_catches_consistent_corruption(tmp_path):
    # point 1 is the solo-mix ways=2 lane; deflating it is internally
    # consistent, so only LRU inclusion vs the ways=1 sibling trips
    spec = tiny_spec()
    plan = plan_from_indices(spec, [{"point": 1, "kind": "corrupt"}])
    res = run_campaign(spec, str(tmp_path),
                       policy=RetryPolicy(max_retries=0, backoff_s=0),
                       hooks=FaultInjector(plan, str(tmp_path)))
    (info,) = res.failed.values()
    assert "monotone" in info["error"]


def test_hang_times_out_and_recovers(tmp_path):
    spec = tiny_spec()
    plan = plan_from_indices(spec, [{"point": 0, "kind": "hang",
                                     "hang_s": 0.6}])
    res = run_campaign(spec, str(tmp_path),
                       policy=RetryPolicy(max_retries=1, timeout_s=0.15,
                                          backoff_s=0.01),
                       hooks=FaultInjector(plan, str(tmp_path)))
    assert res.completed == 4 and not res.failed


def test_fault_plan_validation():
    spec = tiny_spec()
    with pytest.raises(ValueError, match="outside"):
        plan_from_indices(spec, [{"point": 99, "kind": "crash"}])
    with pytest.raises(ValueError, match="kind"):
        plan_from_indices(spec, [{"point": 0, "kind": "gremlin"}])


# --------------------------------------------------------------------------
# mesh-sharded batched execution
# --------------------------------------------------------------------------
def _all_device_mesh():
    """A sweep mesh over every visible device — one on a plain CPU
    host, four under CI's XLA_FLAGS=--xla_force_host_platform_
    device_count=4 (which also exercises lane padding)."""
    import jax

    from repro.launch.mesh import make_sweep_mesh
    return make_sweep_mesh(jax.devices())


def test_mesh_and_batched_manifests_identical_to_sequential(tmp_path):
    """Tentpole acceptance: strictly sequential (batch_points=1),
    vmapped-batched, and mesh-sharded executions of the same spec write
    byte-identical manifests."""
    spec = tiny_spec(6)
    seq = run_campaign(spec, str(tmp_path / "seq"), batch_points=1)
    bat = run_campaign(spec, str(tmp_path / "bat"))
    msh = run_campaign(spec, str(tmp_path / "mesh"),
                       mesh=_all_device_mesh())
    assert seq.completed == bat.completed == msh.completed == 6
    assert canon(seq.manifest) == canon(bat.manifest) == canon(msh.manifest)


def test_quarantine_mid_batch_stays_per_point(tmp_path):
    """A NaN-poisoned point inside a batched lane program is
    quarantined alone; its batchmates complete from the same batch."""
    spec = tiny_spec(6)
    plan = plan_from_indices(spec, [{"point": 2, "kind": "nan"}])
    res = run_campaign(spec, str(tmp_path), mesh=_all_device_mesh(),
                       policy=RetryPolicy(max_retries=0, backoff_s=0),
                       hooks=FaultInjector(plan, str(tmp_path)))
    assert res.manifest["counts"] == {"total": 6, "completed": 5,
                                      "failed": 1}
    (info,) = res.failed.values()
    assert "finite" in info["error"]


def test_crash_mid_batch_resume_bit_identical_property():
    """Hypothesis: killing the process at a random point inside a
    mesh-sharded batch, then resuming, lands on the sequential run's
    exact manifest — for several batch sizes."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    spec = example_spec(points=4, window_bursts=128)
    mesh = _all_device_mesh()
    with tempfile.TemporaryDirectory() as clean_dir:
        clean = run_campaign(spec, clean_dir, batch_points=1)
        baseline = canon(clean.manifest)

        @settings(max_examples=6, deadline=None)
        @given(kill_at=st.integers(0, 3), batch=st.sampled_from([2, 4]))
        def prop(kill_at, batch):
            with tempfile.TemporaryDirectory() as d:
                plan = plan_from_indices(spec, [
                    {"point": kill_at, "kind": "crash"}])
                res, _ = _run_until_done(
                    spec, d, plan,
                    RetryPolicy(max_retries=1, backoff_s=0),
                    mesh=mesh, batch_points=batch)
                assert not res.failed
                assert canon(res.manifest) == baseline

        prop()


# --------------------------------------------------------------------------
# crash-resume property: random kill prefix == uninterrupted run
# --------------------------------------------------------------------------
def test_crash_resume_bit_identical_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    spec = example_spec(points=3, window_bursts=128)
    with tempfile.TemporaryDirectory() as clean_dir:
        clean = run_campaign(spec, clean_dir)
        baseline = canon(clean.manifest)

        @settings(max_examples=8, deadline=None)
        @given(kill_at=st.integers(0, 2), second_kill=st.integers(0, 2))
        def prop(kill_at, second_kill):
            with tempfile.TemporaryDirectory() as d:
                plan = plan_from_indices(spec, [
                    {"point": kill_at, "kind": "crash"},
                    {"point": second_kill, "kind": "torn"},
                ])
                res, _ = _run_until_done(spec, d, plan, RetryPolicy(
                    max_retries=1, backoff_s=0))
                assert not res.failed
                assert canon(res.manifest) == baseline

        prop()


# --------------------------------------------------------------------------
# cross-backend campaigns: NVDLA + NPU points in one spec
# --------------------------------------------------------------------------
def test_backend_axis_preserves_pre_backend_hashes():
    """Adding the backend axis must not invalidate existing journals:
    an NVDLA ModelSpec's dict (and therefore every point_id) is exactly
    what it was before backend/npu_rows/npu_cols existed."""
    d = ModelSpec(window_bursts=256).to_dict()
    assert d == {"name": "yolov3", "window_bursts": 256,
                 "chunk_bursts": 16, "layer_index": 40}
    assert ModelSpec(**d) == ModelSpec(window_bursts=256)
    # the axis fields do carry physics for NPU points
    nv = ModelSpec(window_bursts=256)
    np8 = ModelSpec(window_bursts=256, backend="npu", npu_rows=8,
                    npu_cols=8)
    np16 = ModelSpec(window_bursts=256, backend="npu")
    from repro.campaign.spec import CampaignPoint, DRAMSpec

    ids = {CampaignPoint(m, GeometrySpec(8, ways=2), MixSpec(),
                         DRAMSpec()).point_id for m in (nv, np8, np16)}
    assert len(ids) == 3


def test_backend_axis_validation():
    with pytest.raises(ValueError, match="backend"):
        ModelSpec(backend="tpu")
    with pytest.raises(ValueError, match="trace sources"):
        ModelSpec(name="transformer_decode")          # nvdla can't GEMM
    with pytest.raises(ValueError, match="layer_index"):
        ModelSpec(backend="npu", layer_index=7)       # dropped from hash
    with pytest.raises(ValueError, match="npu_rows"):
        ModelSpec(npu_rows=8)                         # dropped from hash
    from repro.campaign.spec import mixed_backend_spec

    with pytest.raises(ValueError, match="even"):
        mixed_backend_spec(points=3)


def test_npu_points_trace_through_executor(tmp_path):
    """A pure-NPU campaign runs the unchanged executor + guardrails and
    its journaled counters replay the NPU window exactly."""
    from repro.campaign.spec import mixed_backend_spec
    from repro.core import npu
    from repro.core.cache import simulate_segments

    spec = mixed_backend_spec(4, window_bursts=128)
    res = run_campaign(spec, str(tmp_path))
    assert res.completed == 4 and not res.failed
    npu_points = [p for p in res.manifest["points"]
                  if p["params"]["model"].get("backend") == "npu"]
    assert len(npu_points) == 2
    window = npu.npu_chunks(npu.workload("yolov3"),
                            npu.NPUConfig(rows=8, cols=8),
                            chunk_bursts=16, max_bursts=128)
    for p in npu_points:
        geo = p["params"]["geometry"]
        llc = GeometrySpec(**geo).llc()
        ref = simulate_segments(window, llc)
        assert p["result"]["nvdla_accesses"] == ref.accesses
        assert p["result"]["nvdla_hits"] == ref.hits


def test_mixed_backend_campaign_crash_resume_bit_identical(tmp_path):
    """The satellite acceptance case: an 8-point NVDLA+NPU campaign
    journals, crashes mid-run on each backend's half, and resumes to a
    manifest bit-identical to an uninterrupted run."""
    from repro.campaign.spec import mixed_backend_spec

    spec = mixed_backend_spec(8, window_bursts=256)
    backends = [p.model.backend for p in spec.expand()]
    assert sorted(set(backends)) == ["npu", "nvdla"]
    clean = run_campaign(spec, str(tmp_path / "clean"))
    assert clean.completed == 8 and not clean.failed
    plan = plan_from_indices(spec, [
        {"point": backends.index("nvdla"), "kind": "crash"},
        {"point": backends.index("npu") + 1, "kind": "crash"},
    ])
    res, runs = _run_until_done(spec, str(tmp_path / "faulted"), plan,
                                RetryPolicy(max_retries=1, backoff_s=0))
    assert runs >= 3 and not res.failed
    assert canon(res.manifest) == canon(clean.manifest)


def test_mixed_backend_batched_matches_sequential(tmp_path):
    """Batched (vmapped-lane) execution shards NVDLA and NPU points
    into separate lane programs but must journal identical numbers."""
    from repro.campaign.spec import mixed_backend_spec

    spec = mixed_backend_spec(4, window_bursts=128)
    seq = run_campaign(spec, str(tmp_path / "seq"))
    bat = run_campaign(spec, str(tmp_path / "bat"), batch_points=4)
    assert canon(seq.manifest) == canon(bat.manifest)
