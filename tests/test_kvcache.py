"""Paged KV cache: allocator invariants, addressing, and the partition
property (free list ∪ block tables == the whole pool, no aliasing)."""
from __future__ import annotations

import pytest

from repro.core import traces
from repro.serve import OutOfBlocksError, PagedKVCache
from repro.serve.kvcache import KV_REGION, STATE_REGION


def _pool(num_blocks=8, block_size=4, token_bytes=16):
    return PagedKVCache(num_blocks=num_blocks, block_size=block_size,
                        token_bytes=token_bytes)


def test_admit_reserves_full_budget_up_front():
    kv = _pool(num_blocks=8, block_size=4)
    # prompt 5 + max_new 6 = 11 tokens -> 3 blocks, reserved immediately
    tbl = kv.admit(0, 5, 6)
    assert len(tbl.block_ids) == 3 and tbl.tokens == 5
    assert kv.free_blocks == 5
    # appends stay inside the block-granular reservation (3 blocks hold
    # 12 tokens); the table never grows past it
    for _ in range(7):
        kv.append(0)
    assert kv.table(0).tokens == 12
    with pytest.raises(OutOfBlocksError, match="reservation"):
        kv.append(0)
    kv.check_partition()


def test_alloc_free_reuse_and_no_aliasing():
    kv = _pool(num_blocks=8, block_size=4)
    a = kv.admit(0, 4, 4)          # 2 blocks
    b = kv.admit(1, 4, 4)          # 2 blocks
    assert not set(a.block_ids) & set(b.block_ids), "aliased blocks"
    # fresh pools hand out compact low ids deterministically
    assert a.block_ids == (0, 1) and b.block_ids == (2, 3)
    kv.release(0)
    c = kv.admit(2, 8, 0)          # 2 blocks: LIFO reuses 0,1 hottest-first
    assert c.block_ids == (0, 1)
    kv.check_partition()
    kv.release(1)
    kv.release(2)
    assert kv.free_blocks == 8
    kv.check_partition()


def test_out_of_blocks_and_duplicate_rid():
    kv = _pool(num_blocks=4, block_size=4)
    kv.admit(0, 8, 4)              # 3 blocks
    assert not kv.can_admit(8)
    with pytest.raises(OutOfBlocksError, match="needs 2 blocks"):
        kv.admit(1, 4, 4)
    with pytest.raises(ValueError, match="already admitted"):
        kv.admit(0, 4, 0)
    with pytest.raises(ValueError, match="at least one"):
        kv.admit(2, 0, 4)
    with pytest.raises(KeyError):
        kv.append(9)


def test_addressing_and_read_segments():
    kv = _pool(num_blocks=8, block_size=4, token_bytes=48)
    # 4 tokens x 48 B = 192 B raw, already 64 B line-aligned
    assert kv.block_bytes == 192
    assert kv.block_address(0) == KV_REGION
    assert kv.block_address(3) == KV_REGION + 3 * 192
    kv.admit(0, 6, 2)              # 2 blocks, 6 tokens written
    segs = kv.read_segments(0)
    assert [s.stream for s in segs] == ["kv0", "kv0"]
    assert segs[0].base == kv.block_address(0)
    # full first block: 4 tok x 48 B / 32 B bursts = 6 bursts
    assert segs[0].count == 6
    # partial second block: 2 tok x 48 B -> 3 bursts
    assert segs[1].count == 3
    # tokens= caps the read below the written length (windowed WSS)
    capped = kv.read_segments(0, tokens=3)
    assert len(capped) == 1 and capped[0].count == 5    # ceil(144/32)
    total = sum(s.count for s in kv.read_segments(0))
    assert total == -(-6 * 48 // traces.BURST_BYTES)


def test_region_bounds_are_int32_safe():
    # the exact segment engine carries bases as int32: pools must refuse
    # to span into the state region or past 2**31
    too_many = (STATE_REGION - KV_REGION) // 64 + 1
    with pytest.raises(ValueError, match="state"):
        PagedKVCache(num_blocks=too_many, block_size=1, token_bytes=64)
    with pytest.raises(ValueError, match="int32"):
        PagedKVCache(num_blocks=1024, block_size=1, token_bytes=64,
                     region_base=(1 << 31) - 1024)


def test_snapshot_restore_round_trip():
    kv = _pool(num_blocks=8, block_size=4)
    kv.admit(0, 4, 4)
    kv.admit(1, 6, 2)
    kv.append(0, 2)
    snap = kv.snapshot()
    kv2 = _pool(num_blocks=8, block_size=4)
    kv2.restore(snap)
    assert kv2.table(0) == kv.table(0)
    assert kv2.table(1) == kv.table(1)
    assert kv2.free_blocks == kv.free_blocks
    kv2.release(0)
    kv2.check_partition()
    # the donor pool is untouched by mutations of the restored copy
    assert kv.table(0).tokens == 6


def test_check_partition_catches_corruption():
    kv = _pool(num_blocks=4, block_size=4)
    kv.admit(0, 4, 0)
    kv._free.append(kv.table(0).block_ids[0])      # alias a live block
    with pytest.raises(AssertionError, match="aliased"):
        kv.check_partition()
    kv2 = _pool(num_blocks=4, block_size=4)
    kv2._free.pop()                                 # leak a block
    with pytest.raises(AssertionError, match="leaked"):
        kv2.check_partition()


def test_partition_property_random_walk():
    """Plain-random analogue of the hypothesis property below (runs even
    without hypothesis installed)."""
    import numpy as np

    rng = np.random.default_rng(0)
    kv = _pool(num_blocks=16, block_size=4)
    live: list[int] = []
    next_rid = 0
    for _ in range(300):
        op = rng.integers(3)
        if op == 0:
            prompt = int(rng.integers(1, 12))
            new = int(rng.integers(0, 12))
            try:
                kv.admit(next_rid, prompt, new)
                live.append(next_rid)
                next_rid += 1
            except OutOfBlocksError:
                pass
        elif op == 1 and live:
            rid = live[rng.integers(len(live))]
            try:
                kv.append(rid)
            except OutOfBlocksError:
                pass
        elif op == 2 and live:
            kv.release(live.pop(rng.integers(len(live))))
        kv.check_partition()
    for rid in live:
        kv.release(rid)
    assert kv.free_blocks == 16
    kv.check_partition()


def test_partition_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(
        st.one_of(
            st.tuples(st.just("admit"), st.integers(1, 12),
                      st.integers(0, 12)),
            st.tuples(st.just("append"), st.integers(0, 7),
                      st.just(0)),
            st.tuples(st.just("release"), st.integers(0, 7),
                      st.just(0)),
        ),
        min_size=1, max_size=60)

    @settings(max_examples=60, deadline=None)
    @given(ops=ops)
    def prop(ops):
        kv = _pool(num_blocks=12, block_size=4)
        live: list[int] = []
        next_rid = 0
        for op, a, b in ops:
            if op == "admit":
                try:
                    kv.admit(next_rid, a, b)
                    live.append(next_rid)
                    next_rid += 1
                except OutOfBlocksError:
                    pass
            elif op == "append" and live:
                try:
                    kv.append(live[a % len(live)])
                except OutOfBlocksError:
                    pass
            elif op == "release" and live:
                kv.release(live.pop(a % len(live)))
            kv.check_partition()
        while live:
            kv.release(live.pop())
        assert kv.free_blocks == 12
        kv.check_partition()

    prop()
