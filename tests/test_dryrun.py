"""Dry-run integration: one full cell through the real entrypoint.

Runs ``repro.launch.dryrun`` in a subprocess (the 512-placeholder-device
world must not leak into this test process) for a cheap cell on the
single-pod production mesh, and checks the artifact: compile succeeded,
roofline terms present, collective inventory parsed.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_cell_end_to_end(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "decode_32k",
         "--mesh", "pod", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=540, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    path = tmp_path / "pod" / "whisper-tiny__decode_32k.json"
    out = json.loads(path.read_text())
    assert "error" not in out
    assert out["n_devices"] == 256
    r = out["roofline"]
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert set(r) >= {"dominant", "roofline_fraction", "collective_s"}
    assert out["memory"]["temp_bytes"] > 0
    assert out["cost"]["flops"] > 0


def test_sweep_artifacts_complete_and_clean():
    """The committed 80-cell sweep must be complete: every cell either
    compiled or is a documented skip; zero errors."""
    base = os.path.join(REPO, "experiments/artifacts/dryrun")
    if not os.path.isdir(base):
        pytest.skip("sweep artifacts not present")
    total = ok = skipped = 0
    for mesh in ("pod", "multipod"):
        d = os.path.join(base, mesh)
        for name in os.listdir(d):
            with open(os.path.join(d, name)) as f:
                c = json.load(f)
            total += 1
            assert "error" not in c, f"{mesh}/{name} failed"
            if "skipped" in c:
                skipped += 1
                assert "full O(L^2) attention" in c["skipped"]
            else:
                ok += 1
                assert c["roofline"]["compute_s"] >= 0
    assert total == 80, f"expected 80 cells, found {total}"
    assert ok == 66 and skipped == 14
