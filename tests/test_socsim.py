"""Token-level SoC memory pipeline (paper Fig. 2) under FAME-1."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cache import LLCConfig, sequential_burst_trace, simulate_trace
from repro.core.dram import DRAMConfig
from repro.core.socsim import simulate_dbb_stream

LLC = LLCConfig(size_bytes=4096, ways=4, block_bytes=64)
T = 48


def _trace():
    # two interleaved sequential streams, NVDLA-style
    a = sequential_burst_trace(T // 2, 32, 1, base=0)
    b = sequential_burst_trace(T // 2, 32, 1, base=1 << 20)
    return jnp.stack([a, b], axis=1).reshape(-1).astype(jnp.int64)


def test_pipeline_hits_match_exact_cache_sim():
    addrs = _trace()
    res = simulate_dbb_stream(addrs, llc=LLC)
    blocks = (addrs // LLC.block_bytes).astype(jnp.int32)
    hits = simulate_trace(blocks, sets=LLC.sets, ways=LLC.ways)
    # hit <=> latency == t_llc_hit (20)
    np.testing.assert_array_equal(np.asarray(res.latencies == 20),
                                  np.asarray(hits))


def test_spatial_locality_latency():
    """Sequential 32 B bursts with 64 B blocks: alternating miss/hit."""
    addrs = sequential_burst_trace(32, 32, 1).astype(jnp.int64)
    res = simulate_dbb_stream(addrs, llc=LLC)
    lats = np.asarray(res.latencies)
    assert (lats[1::2] == 20).all(), "second burst of each block must hit"
    assert (lats[0::2] > 20).all(), "first burst of each block must miss"


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_fame1_stall_invariance_full_pipeline(seed):
    """The paper's property on the paper's own topology: per-access
    latencies and total cycles are identical under random host stalls."""
    addrs = _trace()
    ref = simulate_dbb_stream(addrs, llc=LLC)
    h = 6 * T
    stalls = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.35, (h, 2))
    out = simulate_dbb_stream(addrs, llc=LLC, host_stalls=stalls)
    np.testing.assert_array_equal(np.asarray(ref.latencies),
                                  np.asarray(out.latencies))
    assert int(ref.total_cycles) == int(out.total_cycles)


def test_dram_row_locality_visible_through_pipeline():
    dram = DRAMConfig()
    # all misses (tiny 1-block llc), sequential rows -> mostly row hits
    tiny = LLCConfig(size_bytes=64, ways=1, block_bytes=64)
    seq = (jnp.arange(T, dtype=jnp.int64) * 64)
    res = simulate_dbb_stream(seq, llc=tiny, dram=dram)
    lats = np.asarray(res.latencies)
    miss_lats = lats[lats > 20]
    row_hit = 20 + dram.t_cas_cycles
    assert (miss_lats == row_hit).mean() > 0.8
