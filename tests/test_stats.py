"""Nearest-rank percentiles: the shared quantile helper.

Pins the ``serve/engine`` off-by-one fix: nearest-rank is
``k = ceil(n * q / 100)`` clamped to [1, n] — the old inline form
truncated ``q * n`` to int *before* the ceiling division, dropping a
rank for fractional percentiles, and never clamped the degenerate
windows (empty / single-element lists).
"""
from __future__ import annotations

import pytest

from repro.utils.stats import latency_summary, nearest_rank


class TestNearestRank:
    def test_degenerate_windows(self):
        assert nearest_rank([], 99) == 0.0
        assert nearest_rank([5.0], 0) == 5.0
        assert nearest_rank([5.0], 50) == 5.0
        assert nearest_rank([5.0], 100) == 5.0

    def test_exact_small_lists(self):
        vals = [1.0, 2.0, 3.0, 4.0]
        assert nearest_rank(vals, 25) == 1.0
        assert nearest_rank(vals, 50) == 2.0
        assert nearest_rank(vals, 75) == 3.0
        assert nearest_rank(vals, 100) == 4.0
        assert nearest_rank(vals, 1) == 1.0

    def test_fractional_percentile_regression(self):
        # ceil(3 * 33.35 / 100) = ceil(1.0005) = 2; the old
        # int-truncate-then-divide form returned rank 1
        assert nearest_rank([1.0, 2.0, 3.0], 33.35) == 2.0

    def test_p99_on_small_samples_is_max(self):
        # with n < 100 samples the 99th nearest-rank is the maximum
        for n in (1, 2, 10, 99):
            vals = [float(i) for i in range(n)]
            assert nearest_rank(vals, 99) == vals[-1]
        vals = [float(i) for i in range(200)]
        assert nearest_rank(vals, 99) == 197.0   # ceil(198.0) - 1

    def test_latency_summary_fields(self):
        s = latency_summary([3.0, 1.0, 2.0])
        assert s == {"n": 3, "mean": 2.0, "p50": 2.0, "p99": 3.0,
                     "wcet": 3.0}
        assert latency_summary([]) == {"n": 0, "mean": 0.0, "p50": 0.0,
                                       "p99": 0.0, "wcet": 0.0}


class TestNearestRankProperties:
    def test_properties(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, strategies as st

        finite = st.floats(allow_nan=False, allow_infinity=False,
                           width=32)

        @given(st.lists(finite, min_size=1, max_size=50),
               st.floats(0, 100), st.floats(0, 100))
        def check(vals, q1, q2):
            vals = sorted(vals)
            r1, r2 = nearest_rank(vals, q1), nearest_rank(vals, q2)
            assert r1 in vals                      # membership
            if q1 <= q2:
                assert r1 <= r2                    # monotone in q
            assert nearest_rank(vals, 100) == vals[-1]
            assert nearest_rank(vals, 0) == vals[0]

        check()

    def test_engine_alias_is_shared_helper(self):
        # the serving engine must not regrow a private copy
        from repro.serve import engine

        assert engine._nearest_rank is nearest_rank
