"""Cycle-token NoC switch + SoC farm: bit-exactness and QoS shape.

The acceptance bar for the farm subsystem: the FAME-1 token-bundle
switch must be bit-identical to the per-cycle reference for *every*
bundle size — including bundles that do not divide the cycle count —
and the farm's victim tail must show the Fig. 6 QoS story (superlinear
p99 in co-runner nodes, way partitioning recovering it).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig
from repro.core.fame1 import chunked_scan
from repro.core.farm import FarmConfig, farm_schedule, simulate_farm
from repro.core.noc import (NoCConfig, NoCOverflowError, NoCSwitch,
                            simulate_reference)
from repro.core.sweep import MixConfig, interference_lane_metrics

GEOMETRIES = ((3, 40, 0), (4, 33, 2))        # (ports, T, link_latency)
BUNDLES = (1, 7, 64)                          # 7 divides nothing here


def _random_schedule(rng, ports: int, cycles: int) -> np.ndarray:
    """Each port injects ~60% of cycles toward a random egress."""
    dests = rng.integers(-2, ports, size=(cycles, ports))
    return np.where(dests >= 0, dests, -1)


def _assert_same(a, b, ctx: str) -> None:
    for f in ("deliver_cycle", "egress", "src", "latency"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f"{ctx}: {f} diverged")


class TestSwitchParity:
    def test_bundles_match_reference(self):
        rng = np.random.default_rng(0)
        for ports, cycles, link in GEOMETRIES:
            cfg = NoCConfig(ports=ports, link_latency=link,
                            queue_depth=cycles)
            for trial in range(3):
                sched = _random_schedule(rng, ports, cycles)
                ref = simulate_reference(sched, cfg)
                assert ref.deliver_cycle.shape[0] == int(
                    (sched >= 0).sum())
                for bundle in BUNDLES:
                    got = NoCSwitch(cfg).simulate(sched,
                                                  bundle_cycles=bundle)
                    _assert_same(got, ref,
                                 f"ports={ports} link={link} "
                                 f"trial={trial} bundle={bundle}")

    def test_farm_schedule_parity_nondividing_bundle(self):
        farm = FarmConfig(nodes=2)
        sched = farm_schedule(40, farm)
        cfg = NoCConfig(ports=4, link_latency=farm.link_latency)
        ref = simulate_reference(sched, cfg)
        for bundle in (5, 13):
            got = NoCSwitch(cfg).simulate(sched, bundle_cycles=bundle)
            _assert_same(got, ref, f"farm bundle={bundle}")
            assert got.host_steps < ref.cycles_run   # batching happened

    def test_source_latencies_in_fifo_order(self):
        cfg = NoCConfig(ports=3, link_latency=1, queue_depth=16)
        sched = np.full((12, 3), -1)
        sched[::2, 0] = 2     # victim every other cycle
        sched[:, 1] = 2       # co-runner every cycle, same egress
        res = NoCSwitch(cfg).simulate(sched)
        lat = res.source_latencies(0)
        assert lat.shape[0] == 6
        assert np.all(lat >= cfg.link_latency)

    def test_overflow_raises_in_both_implementations(self):
        # two saturating sources, one egress, depth 1: the loser of
        # round-robin accumulates a backlog its FIFO cannot hold
        cfg = NoCConfig(ports=2, link_latency=0, queue_depth=1)
        sched = np.full((8, 2), 1)
        with pytest.raises(NoCOverflowError):
            simulate_reference(sched, cfg)
        with pytest.raises(NoCOverflowError):
            NoCSwitch(cfg).simulate(sched)

    def test_schedule_validation(self):
        cfg = NoCConfig(ports=2)
        with pytest.raises(ValueError):
            simulate_reference(np.full((4, 3), -1), cfg)   # wrong width
        with pytest.raises(ValueError):
            simulate_reference(np.full((4, 2), 2), cfg)    # egress >= ports


class TestChunkedScan:
    """fame1.chunked_scan: bundle-size invariance of the host batching."""

    @staticmethod
    def _step(carry, x, active):
        i, acc = carry
        return (i + active.astype(jnp.int32),
                acc + jnp.where(active, x, 0)), acc + x

    def test_invariant_to_chunk_len(self):
        xs = jnp.arange(13, dtype=jnp.int32)
        ref = None
        for chunk in (1, 3, 8, 64):
            carry, ys, bundles = chunked_scan(
                self._step, (jnp.int32(0), jnp.int32(0)), xs,
                cont_fn=lambda c: jnp.bool_(True), chunk_len=chunk)
            got = (int(carry[0]), int(carry[1]),
                   np.asarray(ys)[:13].tolist())
            if ref is None:
                ref = got
                assert ref[0] == 13 and ref[1] == int(np.arange(13).sum())
            assert got == ref, f"chunk_len={chunk} diverged"

    def test_early_exit_stops_on_bundle_boundary(self):
        xs = jnp.ones(20, dtype=jnp.int32)
        carry, _, bundles = chunked_scan(
            self._step, (jnp.int32(0), jnp.int32(0)), xs,
            cont_fn=lambda c: c[0] < 7, chunk_len=3)
        # bundles run until the predicate fails at a bundle boundary
        assert int(bundles) == 3 and int(carry[0]) == 9


class TestFarmTail:
    def test_qos_shape_and_solo_identity(self):
        llc = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64)
        dram = DRAMConfig()
        p99 = {}
        for n, mask in ((0, None), (2, None), (2, 0x0F)):
            res = simulate_farm(llc=llc, dram=dram,
                                farm=FarmConfig(nodes=n, way_mask=mask),
                                max_bursts=512)
            steady = np.sort(res.steady())
            p99[(n, mask)] = steady[min(steady.shape[0] - 1,
                                        int(np.ceil(steady.shape[0]
                                                    * 0.99)) - 1)]
            np.testing.assert_array_equal(
                res.total_latency, res.noc_latency + res.mem_latency)
            if n == 0:
                from repro.core.farm import victim_window

                ref = interference_lane_metrics(
                    victim_window("nvdla", max_bursts=512) * 2,
                    llc=llc, dram=dram, mix=MixConfig(0, "l1"))
                assert res.metrics == ref
        assert p99[(2, None)] > p99[(0, None)]
        assert p99[(2, 0x0F)] < p99[(2, None)]

    def test_npu_victim_backend(self):
        llc = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64)
        res = simulate_farm(llc=llc, dram=DRAMConfig(),
                            farm=FarmConfig(nodes=1, passes=1),
                            backend="npu", max_bursts=256)
        assert res.requests == res.total_latency.shape[0] > 0

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="backend"):
            simulate_farm(llc=LLCConfig(), dram=DRAMConfig(),
                          backend="tpu")
