"""Decode ≡ prefill parity: one-token decode must reproduce full-seq logits.

For each architecture family: run the full-sequence forward over S tokens,
then prefill on the first S-1 tokens and a single `decode_step` for token
S-1 — the decode logits must match the forward logits at the last position.
This exercises every cache kind (dense KV, rolling SWA buffer, SSM state,
RG-LRU state, whisper self+cross).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro import models
from repro.configs import get_smoke_config
from repro.data.synthetic import make_batch
from repro.types import param_values

BATCH, SEQ = 2, 32

# mamba2 stores its conv tails in bf16: a handful of near-zero logits
# overshoot the shared tolerance by bounded rounding (measured max abs
# 0.047 single-step / 0.20 after 4 steps at smoke size).  Widen the
# absolute floor for that arch instead of xfailing it away — a genuine
# SSM state-caching bug produces O(1)+ divergence and still fails.
ATOL_SINGLE = {"mamba2-130m": 0.08}
ATOL_MULTI = {"mamba2-130m": 0.3}

FAMILY_REPS = [
    "deepseek-7b",        # dense GQA
    "qwen2-0.5b",         # dense, qkv bias
    "chatglm3-6b",        # partial rotary
    "mixtral-8x7b",       # MoE + sliding window (rolling cache)
    "grok-1-314b",        # MoE + softcap
    "mamba2-130m",        # SSM state cache
    "recurrentgemma-9b",  # hybrid: RG-LRU + local attn
    "whisper-tiny",       # enc-dec: self + cross cache
    "internvl2-26b",      # VLM: patch prefix
    "granite-3-8b",       # dense GQA
]


def _parity_config(arch):
    """MoE: token dropping differs between a 64-token prefill and a 2-token
    decode group by construction (capacity is per-group).  Parity is only
    exact under a no-drop capacity, so raise the factor to num_experts."""
    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_decode_matches_forward(arch):
    cfg = _parity_config(arch)
    params = param_values(models.init_params(jax.random.PRNGKey(0), cfg))
    batch = make_batch(cfg, BATCH, SEQ, seed=1)

    # full-sequence reference
    full_logits = models.forward(params, batch, cfg, mode="prefill")
    ref = full_logits[:, -1, :]

    # prefill on S-1 tokens, then decode token S-1
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :-1]
    pre_batch.pop("labels", None)
    cache_len = SEQ + 8
    logits_pre, caches, t_next = models.prefill(params, pre_batch, cfg, cache_len)

    last_tok = batch["tokens"][:, -1:]
    dec_logits, _ = models.decode_step(params, caches, last_tok, t_next, cfg)

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=ATOL_SINGLE.get(arch, 2e-2),
        err_msg=f"{arch}: decode logits diverge from full forward")


@pytest.mark.parametrize("arch",
                         ["deepseek-7b", "mamba2-130m", "recurrentgemma-9b"])
def test_multi_step_decode_consistency(arch):
    """Decoding 4 tokens autoregressively == forward over the extended seq.

    Tolerances allow bf16 cache-storage rounding (conv tails are stored
    bf16); the divergence is bounded, not compounding — checked per step.
    """
    cfg = _parity_config(arch)
    params = param_values(models.init_params(jax.random.PRNGKey(0), cfg))
    batch = make_batch(cfg, BATCH, SEQ, seed=2)
    n_dec = 4

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, : SEQ - n_dec]
    pre_batch.pop("labels", None)
    _, caches, t = models.prefill(params, pre_batch, cfg, SEQ + 8)

    outs = []
    for i in range(n_dec):
        tok = batch["tokens"][:, SEQ - n_dec + i : SEQ - n_dec + i + 1]
        logits, caches = models.decode_step(params, caches, tok, t, cfg)
        outs.append(logits)
        t = t + 1

    full = models.forward(params, batch, cfg, mode="prefill")
    for i in range(n_dec):
        np.testing.assert_allclose(
            np.asarray(outs[i], np.float32),
            np.asarray(full[:, SEQ - n_dec + i, :], np.float32),
            rtol=7e-2, atol=ATOL_MULTI.get(arch, 7e-2),
            err_msg=f"{arch}: step {i} diverges")
