"""Paper-core tests: descriptor exactness, cache/DRAM sims, model-vs-sim
cross-validation, and reproduction of the paper's headline numbers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.core import yolov3
from repro.core.accelerator import AccelConfig, MemSystemConfig
from repro.core.cache import (
    LLCConfig,
    hit_rate,
    sequential_burst_trace,
    simulate_trace,
)
from repro.core.dram import DRAMConfig, access_latencies, row_hit_rate
from repro.core.quant import calibrate, dequantize, quantize, quantize_conv_weights
from repro.core.runtime import compile_network
from repro.core.soc import (
    interference_sweep,
    llc_sweep,
    platform_table,
    run_yolov3,
)


# --------------------------------------------------------------------------
# network descriptor
# --------------------------------------------------------------------------
def test_yolov3_descriptor_matches_paper():
    assert abs(yolov3.total_gops() - 66.0) < 1.0, "paper: 66 GOP/frame"
    convs = [l for l in yolov3.LAYERS if l.kind == "conv"]
    assert len(convs) == 75                      # darknet yolov3.cfg
    assert 60e6 < yolov3.total_weight_bytes() < 64e6   # ~62M params
    yolos = [l for l in yolov3.LAYERS if l.kind == "yolo"]
    assert [(l.h, l.w) for l in yolos] == [(13, 13), (26, 26), (52, 52)]


def test_command_stream_split():
    stream = compile_network()
    # paper: convs + shortcuts on NVDLA; upsample/route/yolo + casts on CPU
    assert all(op.layer.kind in ("conv", "shortcut") for op in stream.accel_ops)
    kinds = {op.kind for op in stream.cpu_ops}
    assert {"upsample", "route", "yolo", "cast"} <= kinds
    assert stream.total_macs == yolov3.total_macs()


# --------------------------------------------------------------------------
# exact LLC simulator
# --------------------------------------------------------------------------
def test_llc_sequential_stream_hit_rate_closed_form():
    """Exact sim must reproduce the 1 - 32/B spatial hit rate the
    accelerator timing model assumes."""
    for block in (32, 64, 128):
        cfg = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=block)
        trace = sequential_burst_trace(4096, 32, block)
        hr = hit_rate(trace, cfg)
        expect = 1.0 - 32.0 / block
        assert abs(hr - expect) < 0.02, (block, hr, expect)


def test_llc_lru_eviction():
    # 1 set x 2 ways: A B A -> hit on A; A B C A -> A was NOT evicted (LRU
    # keeps A over B after the second A touch); A B C B -> B was evicted.
    hits = simulate_trace(jnp.array([0, 1, 0], jnp.int32), sets=1, ways=2)
    assert hits.tolist() == [False, False, True]
    hits = simulate_trace(jnp.array([0, 1, 0, 2, 0, 1], jnp.int32),
                          sets=1, ways=2)
    # after A B A, C evicts B (LRU); A still hits; B misses
    assert hits.tolist() == [False, False, True, False, True, False]


def test_llc_capacity_thrash():
    """A working set larger than the cache in a cyclic pattern -> ~0 hits
    (LRU worst case); smaller -> ~all hits after warmup."""
    cfg = LLCConfig(size_bytes=2048, ways=2, block_bytes=64)  # 32 blocks
    small = jnp.tile(jnp.arange(16, dtype=jnp.int32), 8)
    big = jnp.tile(jnp.arange(64, dtype=jnp.int32), 4)
    assert hit_rate(small, cfg) > 0.8
    assert hit_rate(big, cfg) < 0.05


# --------------------------------------------------------------------------
# DRAM model
# --------------------------------------------------------------------------
def test_dram_row_locality():
    cfg = DRAMConfig()
    seq = jnp.arange(0, 512 * 64, 64, dtype=jnp.int64)      # sequential 64B
    rand = jax.random.permutation(
        jax.random.PRNGKey(0), jnp.arange(512, dtype=jnp.int64)) * 1_000_003
    assert row_hit_rate(seq, cfg) > 0.9
    assert row_hit_rate(rand, cfg) < 0.2


def test_dram_latency_values():
    cfg = DRAMConfig()
    lats = access_latencies(jnp.array([0, 64, 1 << 20], jnp.int64),
                            banks=cfg.banks, row_bytes=cfg.row_bytes,
                            t_cas=cfg.t_cas_cycles, t_rcd=cfg.t_rcd_cycles,
                            t_rp=cfg.t_rp_cycles)
    assert lats[0] == cfg.t_rp_cycles + cfg.t_rcd_cycles + cfg.t_cas_cycles
    assert lats[1] == cfg.t_cas_cycles            # same row
    # different row, same bank layout -> activate again
    assert lats[2] > cfg.t_cas_cycles


# --------------------------------------------------------------------------
# quantization
# --------------------------------------------------------------------------
def test_quant_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 64)) * 0.3
    s = calibrate(x)
    err = jnp.abs(dequantize(quantize(x, s), s) - x)
    assert float(jnp.max(err)) <= float(s) / 2 + 1e-7


def test_quant_conv_weights_per_channel():
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 3, 8, 16))
    w = w * jnp.linspace(0.1, 3.0, 16)            # very different ranges
    q, scale = quantize_conv_weights(w)
    assert q.dtype == jnp.int8 and scale.shape == (16,)
    rel = jnp.abs(dequantize(q, scale) - w) / (jnp.abs(w) + 1e-6)
    assert float(jnp.median(rel)) < 0.05


# --------------------------------------------------------------------------
# the paper's three experiments
# --------------------------------------------------------------------------
def test_baseline_frame_matches_paper():
    r = run_yolov3()
    assert 60 < r.accel_s * 1e3 < 75, "paper: 67 ms on NVDLA"
    assert 55 < r.cpu_s * 1e3 < 75, "paper: 66 ms on the cores"
    assert 6.5 < r.fps < 8.5, "paper: 7.5 fps"


def test_llc_sweep_matches_fig5():
    sw = llc_sweep(sizes_kib=(0.5, 64, 1024, 4096), blocks=(32, 64, 128))
    g = sw["grid"]
    # block-size sensitivity at 1 MiB (paper: 1.01 / 1.25 / 1.51)
    assert abs(g[(1024, 32)] - 1.01) < 0.08
    assert abs(g[(1024, 64)] - 1.25) < 0.12
    assert abs(g[(1024, 128)] - 1.51) < 0.08
    # capacity insensitivity (paper: 1.17 @ 0.5 KiB vs 1.28 @ 64 KiB)
    assert abs(g[(0.5, 64)] - 1.17) < 0.08
    assert abs(g[(64, 64)] - 1.28) < 0.06
    # max speedup 1.56x @ 4 MiB / 128 B
    assert abs(g[(4096, 128)] - 1.56) < 0.06
    # ordering: block size matters more than capacity
    assert g[(4096, 32)] < g[(0.5, 64)] < g[(0.5, 128)]


def test_interference_matches_fig6():
    sw = interference_sweep()
    assert all(abs(v - 1.0) < 1e-9 for v in sw["l1"].values()), \
        "L1-fitting co-runners must not interfere"
    assert abs(sw["llc"][4] - 2.1) < 0.2, "paper: 2.1x at 4 LLC co-runners"
    assert abs(sw["dram"][4] - 2.5) < 0.2, "paper: 2.5x at 4 DRAM co-runners"
    for wss in ("llc", "dram"):
        vals = [sw[wss][n] for n in (0, 1, 2, 3, 4)]
        assert all(b >= a for a, b in zip(vals, vals[1:])), "monotone"
    assert sw["dram"][4] > sw["llc"][4], "DRAM WSS hurts more (paper)"


def test_platform_table_matches_fig4():
    t = platform_table()
    assert 6.5 < t["nvdla (int8)"] < 8.5
    assert 35 < t["titan xp (fp32)"] < 45, "paper: 41 fps"
    assert 300 < t["_meta"]["speedup_vs_rocket"] < 500, "paper: 407x"
    # GPU ~5.5x faster than NVDLA (paper)
    ratio = t["titan xp (fp32)"] / t["nvdla (int8)"]
    assert 4.5 < ratio < 6.5


def test_sim_driven_op_cycles_matches_paper_baseline():
    """mode="simulated": every layer's hit rates come from the exact
    segment simulator on its own DBB trace (LLC state carried across
    ops).  The resulting frame time must still land on the paper's
    67 ms NVDLA baseline — the simulator *drives* the model it used to
    only validate."""
    from repro.core.accelerator import op_stream_hit_rates

    r = run_yolov3(mode="simulated")
    assert 55 < r.accel_s * 1e3 < 80, "paper: 67 ms on NVDLA"
    stream = r.detail["stream"]
    rates = op_stream_hit_rates(stream, MemSystemConfig())
    assert len(rates) == len(stream.accel_ops)
    assert all(0.0 <= h <= 1.0 for hr in rates for h in hr)
    # 64 B blocks over 32 B bursts: spatial locality floors streams near
    # 0.5; ifmap streams may exceed it via producer-ofmap residency
    weighted = [h for hr in rates for h in hr]
    assert 0.35 < sum(weighted) / len(weighted) < 0.9


def test_op_stream_hit_rates_grid_matches_pointwise():
    """The vmapped grid path (the fig5 simulated sweep, now also the
    NPU comparison's substrate) must reproduce the serial pointwise
    rates exactly for every geometry — same fold, same numbers."""
    from repro.core.accelerator import (op_stream_hit_rates,
                                        op_stream_hit_rates_grid)

    stream = compile_network()
    llcs = [LLCConfig(size_bytes=64 * 1024, ways=4, block_bytes=64),
            LLCConfig(size_bytes=256 * 1024, ways=8, block_bytes=64),
            LLCConfig(size_bytes=128 * 1024, ways=2, block_bytes=32)]
    max_ops = 6
    grid = op_stream_hit_rates_grid(stream, llcs, max_ops=max_ops)
    assert len(grid) == len(llcs)
    for llc, rates in zip(llcs, grid):
        mem = MemSystemConfig(llc=llc)
        point = op_stream_hit_rates(stream, mem, max_ops=max_ops)
        assert len(rates) == len(point) == max_ops
        for a, b in zip(rates, point):
            assert a == b, f"grid diverged from pointwise at {llc}"


def test_accel_time_s_mode_validation():
    from repro.core.accelerator import AccelConfig, accel_time_s

    stream = compile_network()
    with pytest.raises(ValueError, match="mode"):
        accel_time_s(stream, acc=AccelConfig(), mem=MemSystemConfig(),
                     mode="cycle-exact")
    with pytest.warns(DeprecationWarning, match="positional"):
        accel_time_s(stream, AccelConfig(), MemSystemConfig())
    with pytest.raises(TypeError, match="acc=/mem="):
        accel_time_s(stream, acc=AccelConfig())


def test_recalibration_agrees_with_simulated_grid():
    """The shipped closed-form constant must stay inside the simulated
    fit's neighbourhood: re-fitting against exact full-frame hit rates
    may not expose a materially better single constant."""
    from repro.core.accelerator import recalibrate_stream_conflict
    from repro.core.sweep import sweep_llc

    sw = sweep_llc(sizes_kib=(0.5, 64, 1024), blocks=(32, 64, 128),
                   window_bursts=20_000)
    cal = recalibrate_stream_conflict(sw.sim_hit_rates)
    assert cal["points"] == 9
    assert cal["rms_fit"] <= cal["rms_shipped"] + 1e-9
    assert cal["rms_shipped"] < 0.25, \
        "closed form has drifted far from the exact simulator"


def test_llc_timing_model_vs_exact_sim():
    """Cross-validation: the closed-form stream hit rate used by the
    timing model agrees with the exact LLC simulator on a real layer's
    interleaved weight+ifmap+ofmap burst streams."""
    from repro.core.accelerator import _stream_hit_rate

    llc = LLCConfig(size_bytes=256 * 1024, ways=8, block_bytes=64)
    mem = MemSystemConfig(llc=llc)
    # interleave three sequential streams at distinct base addresses, as
    # the DBB arbiter does
    n = 2048
    w = sequential_burst_trace(n, 32, 64, base=0)
    i = sequential_burst_trace(n, 32, 64, base=1 << 24)
    o = sequential_burst_trace(n, 32, 64, base=1 << 25)
    trace = jnp.stack([w, i, o], axis=1).reshape(-1)
    hr_sim = hit_rate(trace, llc)
    hr_model = _stream_hit_rate(mem)
    assert abs(hr_sim - hr_model) < 0.05, (hr_sim, hr_model)
