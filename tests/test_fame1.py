"""FAME-1 token simulation: the stall-invariance property.

The paper's core mechanism (sec 3.1): a FAME-1-transformed design is
clock-gated whenever an input token is unavailable, and the *target*
behaviour — state trajectory and output token stream — is bit-identical
for every host stall pattern.  Hypothesis generates random stall
schedules; the property must hold exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.fame1 import Component, FAME1Pipeline, fame1_wrap, run_hosted

N_TOKENS = 12


def _accumulator_step(state, x):
    """A stateful target component: y_t = state + x_t; state' = y_t."""
    y = state + x
    return y, y


def _mac_step(state, x):
    """NVDLA-ish MAC pipe: multiply-accumulate with saturation."""
    acc = jnp.clip(state["acc"] + x["a"] * x["b"], -1e6, 1e6)
    return {"acc": acc}, acc


def _schedule(stalls: list[bool], tokens):
    """Interleave tokens with stall cycles -> (host_tokens, valid_mask)."""
    t = len(tokens)
    valid = jnp.asarray([not s for s in stalls], bool)
    assert int(valid.sum()) == t
    idx = jnp.cumsum(valid.astype(jnp.int32)) - 1
    host_tokens = tokens[jnp.clip(idx, 0, t - 1)]
    return host_tokens, valid


@given(st.lists(st.integers(min_value=0, max_value=3), min_size=N_TOKENS,
                max_size=N_TOKENS))
@settings(max_examples=25, deadline=None)
def test_stall_invariance_accumulator(stall_runs):
    # stall_runs[i] = number of stalled host cycles before token i
    stalls: list[bool] = []
    for r in stall_runs:
        stalls.extend([True] * r)
        stalls.append(False)
    tokens = jnp.arange(1.0, N_TOKENS + 1.0)
    # reference: no stalls at all
    ref_state, ref_out, n = run_hosted(
        _accumulator_step, jnp.float32(0.0), tokens,
        jnp.ones((N_TOKENS,), bool))
    host_tokens, valid = _schedule(stalls, tokens)
    state, out, n2 = run_hosted(_accumulator_step, jnp.float32(0.0),
                                host_tokens, valid)
    assert int(n) == int(n2) == N_TOKENS
    np.testing.assert_array_equal(np.asarray(ref_state), np.asarray(state))
    np.testing.assert_array_equal(np.asarray(ref_out[:N_TOKENS]),
                                  np.asarray(out[:N_TOKENS]))


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_stall_invariance_mac_random_schedules(seed):
    key = jax.random.PRNGKey(seed)
    ka, kb, ks = jax.random.split(key, 3)
    tokens = {"a": jax.random.normal(ka, (N_TOKENS,)),
              "b": jax.random.normal(kb, (N_TOKENS,))}
    # random stall pattern with exactly N_TOKENS valid cycles
    h = 3 * N_TOKENS
    perm = jax.random.permutation(ks, h)
    valid = jnp.zeros((h,), bool).at[perm[:N_TOKENS]].set(True)
    idx = jnp.clip(jnp.cumsum(valid.astype(jnp.int32)) - 1, 0, N_TOKENS - 1)
    host_tokens = jax.tree.map(lambda t: t[idx], tokens)

    ref_state, ref_out, _ = run_hosted(
        _mac_step, {"acc": jnp.float32(0.0)}, tokens,
        jnp.ones((N_TOKENS,), bool))
    state, out, _ = run_hosted(_mac_step, {"acc": jnp.float32(0.0)},
                               host_tokens, valid)
    np.testing.assert_allclose(np.asarray(ref_state["acc"]),
                               np.asarray(state["acc"]), rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(ref_out[:N_TOKENS]),
                               np.asarray(out[:N_TOKENS]), rtol=0, atol=0)


def _make_pipeline():
    """accelerator -> memory-latency stage, as in the paper's Figure 2."""
    accel = Component(
        name="nvdla",
        step_fn=lambda s, x: (s + 1, x * 2.0),      # state counts tokens
        init_state=jnp.int32(0),
        init_output=jnp.float32(0.0))
    memory = Component(
        name="memmodel",
        step_fn=lambda s, x: (s + x, x + s),        # running-sum "latency"
        init_state=jnp.float32(0.0),
        init_output=jnp.float32(0.0))
    return FAME1Pipeline([accel, memory])


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_pipeline_stall_invariance(seed):
    """Back-pressured two-stage pipeline: output stream identical under
    random per-component stalls (simulating host DRAM delays)."""
    tokens = jnp.arange(1.0, 9.0)
    t = tokens.shape[0]
    pipe = _make_pipeline()
    h = 8 * t
    _, ref_out, ref_n = pipe.run(tokens, jnp.zeros((h, 2), bool),
                                 max_host_cycles=h)
    stalls = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.4, (h * 3, 2))
    _, out, n = pipe.run(tokens, stalls, max_host_cycles=h * 3)
    assert int(ref_n) == int(n) == t
    np.testing.assert_array_equal(np.asarray(ref_out), np.asarray(out))


def test_fame1_wrap_gates_state():
    hosted = fame1_wrap(_accumulator_step)
    s0 = jnp.float32(5.0)
    s1, (y, v) = hosted(s0, (jnp.float32(3.0), jnp.bool_(False)))
    assert float(s1) == 5.0 and not bool(v)        # clock-gated
    s2, (y, v) = hosted(s0, (jnp.float32(3.0), jnp.bool_(True)))
    assert float(s2) == 8.0 and bool(v)
