"""Optimized decode paths: split-K attention math + int8 KV cache parity."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_smoke_config
from repro.data.synthetic import make_batch
from repro.models.attention import _attend_decode_splitk, _softcap
from repro.types import param_values

BATCH, SEQ = 2, 32


def test_splitk_math_matches_dense():
    """Per-shard partial softmax + combine == dense softmax attention."""
    cfg = get_smoke_config("grok-1-314b")
    key = jax.random.PRNGKey(0)
    b, s, nq, hd = 2, 64, 4, 16
    q = jax.random.normal(key, (b, 1, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, nq, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, nq, hd))
    t = jnp.int32(40)  # positions > t must be masked
    scale = hd ** -0.5

    for ns in (2, 4, 8):
        out = _attend_decode_splitk(q, k, v, t, cfg, ns, scale)
        # dense reference
        scores = jnp.einsum("blnh,btnh->bnlt", q, k) * scale
        scores = _softcap(scores, cfg.attn_logit_softcap)
        valid = jnp.arange(s) <= t
        scores = jnp.where(valid[None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ref = jnp.einsum("bnlt,btnh->blnh", probs, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("arch", ["deepseek-7b", "grok-1-314b"])
def test_int8_kv_cache_decode_parity(arch):
    """decode with an int8 KV cache tracks the bf16 full forward closely."""
    cfg = dataclasses.replace(get_smoke_config(arch),
                              kv_cache_dtype="int8")
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=float(cfg.num_experts))
    params = param_values(models.init_params(jax.random.PRNGKey(0), cfg))
    batch = make_batch(cfg, BATCH, SEQ, seed=1)

    full = models.forward(params, batch, cfg, mode="prefill")
    ref = full[:, -1, :]

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    pre.pop("labels", None)
    logits_pre, caches, t = models.prefill(params, pre, cfg, SEQ + 8)
    # int8 cache layout present
    blk = caches["blocks"][0] if "blocks" in caches else caches["rem"][0]
    assert blk["k"].dtype == jnp.int8 and "k_scale" in blk

    dec, _ = models.decode_step(params, caches, batch["tokens"][:, -1:], t, cfg)
    # int8 quantization of K/V adds noise; logits must still track closely
    err = np.abs(np.asarray(dec) - np.asarray(ref, np.float32))
    rel = err.max() / (np.abs(np.asarray(ref)).max() + 1e-6)
    assert rel < 0.08, f"{arch}: int8-KV decode diverged (rel {rel:.3f})"


def test_perf_presets_importable():
    from repro.launch.presets import PERF_PRESETS, preset_for

    assert preset_for("qwen2-0.5b", "train_4k") is not None
    assert preset_for("qwen2-0.5b", "decode_32k") is None
    for (arch, shape), p in PERF_PRESETS.items():
        assert set(p) <= {"overrides", "rule_overrides", "microbatches"}
