"""Per-architecture smoke tests: reduced config, one forward/train step on CPU.

Asserts output shapes, finiteness (no NaNs), and that a single SGD step
changes the loss — for every assigned architecture family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import ARCHS, get_smoke_config
from repro.data.synthetic import make_batch
from repro.models.layers import padded_vocab
from repro.types import param_values, validate_params

BATCH, SEQ = 2, 32


def _setup(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = models.init_params(key, cfg)
    validate_params(params)
    values = param_values(params)
    batch = make_batch(cfg, BATCH, SEQ, seed=0)
    return cfg, values, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, values, batch = _setup(arch)
    logits = models.forward(values, batch, cfg, mode="train")
    n_tokens = batch["tokens"].shape[1]
    assert logits.shape == (BATCH, n_tokens, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_structure(arch):
    cfg, values, batch = _setup(arch)

    def loss(v):
        return models.loss_fn(v, batch, cfg)[0]

    l0, grads = jax.value_and_grad(loss)(values)
    assert bool(jnp.isfinite(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
    # plain SGD step must change the loss
    lr = 1e-2
    new_values = jax.tree.map(lambda v, g: v - lr * g.astype(v.dtype), values, grads)
    l1 = loss(new_values)
    assert bool(jnp.isfinite(l1))
    assert float(l1) != float(l0)
