"""LLC way-masking (CAT-style partitioning): the two pinned invariants.

1. **Isolation**: a way outside a traffic class's allocation mask never
   *holds* that class's lines — hits may touch any way (recency refresh,
   Intel CAT semantics), but allocation is confined to the mask, so the
   final tag state proves the fence.
2. **Sentinel/identity**: the full mask (and the batch path's zero
   sentinel) is bit-exactly the unpartitioned scan — same hits, same
   miss runs, same ``LaneMetrics``.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig
from repro.core.sweep import (MixConfig, interference_lane_metrics,
                              interference_lane_metrics_batch,
                              lane_request_latencies, _masked_lane_run,
                              partition_way_sels)

VICTIM_REGION = 0x1000_0000
CORUN_REGION = 0x2000_0000
LLC = LLCConfig(size_bytes=16 * 1024, ways=4, block_bytes=64)  # 64 sets


def _two_class_lane(rng, n_segs: int = 24):
    """Interleaved victim/co-runner segments, both streaming well past
    the 4-way capacity of every set."""
    b, s, c, is_victim = [], [], [], []
    for i in range(n_segs):
        victim = i % 2 == 0
        region = VICTIM_REGION if victim else CORUN_REGION
        b.append(region + int(rng.integers(0, 64)) * 64 * 64)
        s.append(int(rng.choice((32, 64))))
        c.append(int(rng.integers(32, 256)))
        is_victim.append(victim)
    return (np.asarray(b, np.int64), np.asarray(s, np.int64),
            np.asarray(c, np.int64), np.asarray(is_victim, bool))


def _resident_blocks(tags, sets: int):
    """(way, block_byte_addr) pairs of every valid line in the final
    tag state (tags are block // sets per (way, set))."""
    ways = tags.shape[0]
    w, s = np.nonzero(tags != -1)
    blocks = tags[w, s].astype(np.int64) * sets + s
    return w, blocks * 64


class TestIsolation:
    def test_masked_ways_never_hold_foreign_lines(self):
        rng = np.random.default_rng(7)
        for trial in range(5):
            b, s, c, nv = _two_class_lane(rng)
            vm = int(rng.choice((0b0001, 0b0011, 0b0110)))
            sels = partition_way_sels(nv, LLC, vm)
            _, _, (tags, _) = _masked_lane_run(b, s, c, LLC, sels,
                                               return_state=True)
            way, addr = _resident_blocks(np.asarray(tags), LLC.sets)
            is_victim_line = addr < CORUN_REGION
            co = ((1 << LLC.ways) - 1) & ~vm
            for w, victim_line in zip(way, is_victim_line):
                mask = vm if victim_line else co
                assert (mask >> w) & 1, (
                    f"trial {trial}: way {w} holds a "
                    f"{'victim' if victim_line else 'co-runner'} line "
                    f"outside its allocation mask {mask:#x}")

    def test_partition_protects_victim_reuse(self):
        llc = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64)
        from repro.core import traces

        segs = traces.default_dbb_window(max_bursts=512) * 2
        mix = MixConfig(corunners=2, wss="llc")
        dram = DRAMConfig()
        base = interference_lane_metrics(segs, llc=llc, dram=dram, mix=mix)
        part = interference_lane_metrics(segs, llc=llc, dram=dram, mix=mix,
                                         way_mask=0x0F)
        assert part.nvdla_hit_rate > base.nvdla_hit_rate
        assert part.total_cycles < base.total_cycles


class TestSentinelIdentity:
    def test_full_mask_is_bit_exact_unpartitioned(self):
        from repro.core import traces

        llc = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64)
        dram = DRAMConfig()
        segs = traces.default_dbb_window(max_bursts=512)
        full = (1 << llc.ways) - 1
        for n in (0, 2):
            mix = MixConfig(corunners=n, wss="llc" if n else "l1")
            a = interference_lane_metrics(segs, llc=llc, dram=dram,
                                          mix=mix)
            b = interference_lane_metrics(segs, llc=llc, dram=dram,
                                          mix=mix, way_mask=full)
            assert a == b

    def test_batch_mixes_masked_and_unmasked_lanes(self):
        from repro.core import traces

        llc = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64)
        dram = DRAMConfig()
        segs = traces.default_dbb_window(max_bursts=256)
        mix = MixConfig(corunners=2, wss="llc")
        mixes = [MixConfig(0, "l1"), mix, mix, mix]
        masks = [None, None, (1 << llc.ways) - 1, 0x0F]
        batch = interference_lane_metrics_batch(
            segs, llcs=[llc] * 4, drams=[dram] * 4, mixes=mixes,
            way_masks=masks)
        for got, mix_i, mask_i in zip(batch, mixes, masks):
            ref = interference_lane_metrics(segs, llc=llc, dram=dram,
                                            mix=mix_i, way_mask=mask_i)
            assert got == ref

    def test_request_latencies_sum_to_lane_total(self):
        from repro.core import traces

        llc = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64)
        dram = DRAMConfig()
        segs = traces.default_dbb_window(max_bursts=512)
        mix = MixConfig(corunners=2, wss="llc")
        for mask in (None, 0x0F):
            lat, metrics = lane_request_latencies(
                segs, llc=llc, dram=dram, mix=mix, way_mask=mask)
            assert metrics == interference_lane_metrics(
                segs, llc=llc, dram=dram, mix=mix, way_mask=mask)
            # victim chunks carry the victim's share; the co-runner
            # share is the rest — both sides of the identity are exact
            assert lat.shape[0] == 512 // 16
            assert 0 < int(lat.sum()) <= metrics.total_cycles


class TestPartitionWaySels:
    def test_empty_victim_mask_raises(self):
        with pytest.raises(ValueError, match="at least one way"):
            partition_way_sels(np.array([True]), LLC, 0x10)  # beyond ways

    def test_full_mask_means_unpartitioned_for_both_classes(self):
        full = (1 << LLC.ways) - 1
        sels = partition_way_sels(np.array([True, False]), LLC, full)
        assert sels.tolist() == [full, full]

    def test_complement_assignment(self):
        sels = partition_way_sels(np.array([True, False]), LLC, 0b0011)
        assert sels.tolist() == [0b0011, 0b1100]
