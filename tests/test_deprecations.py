"""Deprecation warnings must attribute to the *caller's* line.

Repo-wide convention: every public deprecated entry point warns with
``stacklevel=2`` from its own frame, so the warning points at the user
code that needs updating — not at a helper inside the library.  Each
test calls a deprecated form through a one-line lambda and asserts the
recorded warning carries this file and that lambda's line number.
"""
from __future__ import annotations

import warnings

import numpy as np
import pytest

from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig


def _sole_deprecation(fn):
    """Run ``fn`` and return the single DeprecationWarning it emits."""
    with warnings.catch_warnings(record=True) as log:
        warnings.simplefilter("always")
        fn()
    deps = [w for w in log if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, f"expected exactly 1 DeprecationWarning, " \
                           f"got {[str(w.message) for w in deps]}"
    return deps[0]


def _assert_points_here(w, fn):
    assert w.filename == __file__, (
        f"warning attributed to {w.filename}, not the caller")
    assert w.lineno == fn.__code__.co_firstlineno, (
        f"warning attributed to line {w.lineno}, caller is at "
        f"{fn.__code__.co_firstlineno}")


def test_simulate_dbb_stream_positional_configs():
    from repro.core.socsim import simulate_dbb_stream

    addrs = np.arange(0, 8 * 64, 64, dtype=np.int64)
    llc = LLCConfig()
    fn = lambda: simulate_dbb_stream(addrs, llc)  # noqa: E731
    _assert_points_here(_sole_deprecation(fn), fn)


def test_simulate_dbb_segments_positional_configs():
    from repro.core.socsim import simulate_dbb_segments
    from repro.core.traces import Segment

    segs = [Segment(base=0, stride=64, count=8, stream="weight")]
    llc = LLCConfig()
    fn = lambda: simulate_dbb_segments(segs, llc)  # noqa: E731
    _assert_points_here(_sole_deprecation(fn), fn)


def test_accel_time_s_positional_configs():
    from repro.core.accelerator import accel_time_s
    from repro.core.runtime import compile_network
    from repro.core.soc import SoCConfig

    soc = SoCConfig()
    stream = compile_network(conv_buf_bytes=soc.accel.conv_buf_bytes)
    fn = lambda: accel_time_s(stream, soc.accel, soc.mem)  # noqa: E731
    _assert_points_here(_sole_deprecation(fn), fn)


def test_engine_generate_shim():
    pytest.importorskip("jax")
    import jax

    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serve import ServeEngine
    from repro.types import param_values

    cfg = get_smoke_config("qwen2-0.5b")
    params = param_values(init_params(jax.random.PRNGKey(0), cfg))
    eng = ServeEngine(cfg, params, cache_len=16, max_slots=2, eos_id=0)
    batch = {"tokens": np.full((1, 4), 3, np.int64)}
    fn = lambda: eng.generate(batch, 2)  # noqa: E731
    _assert_points_here(_sole_deprecation(fn), fn)


@pytest.mark.parametrize("name", ["batched_hits", "batched_hit_rates",
                                  "batched_hits_per_trace"])
def test_expanded_trace_lanes(name):
    from repro.core import sweep

    addrs = np.arange(0, 8 * 64, 64, dtype=np.int64)
    arg = addrs[None, :] if name == "batched_hits_per_trace" else addrs
    fn = lambda: getattr(sweep, name)(arg, [LLCConfig()])  # noqa: E731
    _assert_points_here(_sole_deprecation(fn), fn)


def test_keyword_calls_do_not_warn():
    from repro.core.socsim import simulate_dbb_segments, simulate_dbb_stream
    from repro.core.traces import Segment

    addrs = np.arange(0, 4 * 64, 64, dtype=np.int64)
    with warnings.catch_warnings(record=True) as log:
        warnings.simplefilter("always")
        simulate_dbb_stream(addrs, llc=LLCConfig(), dram=DRAMConfig())
        simulate_dbb_segments([Segment(base=0, stride=64, count=4,
                                       stream="weight")],
                              llc=LLCConfig())
    assert not [w for w in log
                if issubclass(w.category, DeprecationWarning)]
