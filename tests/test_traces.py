"""Compressed DBB traces + segment engine: bit-exact parity with the
per-access reference simulator on every dispatch path (closed form,
per-set round scan, prefix/suffix split, expand fallback)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traces
from repro.core.cache import (
    LLCConfig,
    _scan_trace,
    cold_state,
    hit_rate,
    hit_rate_segments,
    simulate_segments,
)
from repro.core.traces import Segment
from repro.utils.env import as_address_array, x64_enabled

CFG = LLCConfig(size_bytes=4096, ways=4, block_bytes=64)   # 16 sets


def _assert_parity(segs, cfg, *, expect=None):
    """Compressed result must equal expanding + exact scanning: same hit
    count AND bit-identical (tags, age) state."""
    res = simulate_segments(segs, cfg)
    blocks = (traces.expand(segs) // cfg.block_bytes).astype(np.int32)
    state, hits = _scan_trace(cold_state(cfg.sets, cfg.ways),
                              jnp.asarray(blocks),
                              sets=cfg.sets, ways=cfg.ways)
    assert res.accesses == len(blocks)
    assert res.hits == int(hits.sum())
    np.testing.assert_array_equal(np.asarray(res.state[0]),
                                  np.asarray(state[0]))
    np.testing.assert_array_equal(np.asarray(res.state[1]),
                                  np.asarray(state[1]))
    if expect is not None:
        for key, val in expect.items():
            assert getattr(res, key) == val, (key, getattr(res, key), val)
    return res


def test_closed_form_cold_sweep():
    # disjoint full sweep -> O(1) analytic path, hits = n - blocks
    res = _assert_parity([Segment(0, 32, 20_000)], CFG,
                         expect={"closed_form_segments": 1,
                                 "round_scanned_segments": 0})
    assert res.hits == 20_000 - 10_000


def test_restreamed_region_splits_prefix_suffix():
    # second pass over the same bytes: warm prefix round-scanned, the
    # provably-evicted suffix closed-formed
    res = _assert_parity([Segment(0, 32, 20_000), Segment(0, 32, 20_000)],
                         CFG)
    assert res.closed_form_segments >= 2
    assert res.round_scanned_segments >= 1


def test_small_warm_segments_round_scan():
    _assert_parity([Segment(0, 32, 40), Segment(64, 32, 10),
                    Segment(0, 32, 40)], CFG,
                   expect={"closed_form_segments": 0,
                           "round_scanned_segments": 3})


def test_unaligned_bases_and_strides():
    _assert_parity([Segment(17, 32, 1000), Segment(5000, 48, 333)], CFG)


def test_stride_above_block_expands():
    _assert_parity([Segment(0, 256, 500)], CFG,
                   expect={"expanded_segments": 1})


def test_single_set_geometry():
    _assert_parity([Segment(0, 32, 500)],
                   LLCConfig(size_bytes=128, ways=2, block_bytes=64))
    _assert_parity([Segment(0, 32, 9000), Segment(0, 32, 9000)],
                   LLCConfig(size_bytes=128, ways=2, block_bytes=64))


def test_interleaved_window_parity():
    win = traces.default_dbb_window(max_bursts=1500, chunk_bursts=16)
    _assert_parity(win, CFG)


def test_network_trace_prefix_parity():
    segs = traces.window(traces.network_trace(max_ops=6), 50_000)
    _assert_parity(segs, LLCConfig(size_bytes=64 * 1024, ways=8,
                                   block_bytes=64))


def test_hit_rate_segments_matches_hit_rate():
    segs = [Segment(0, 32, 5000), Segment(1 << 20, 32, 3000)]
    hr_seg = hit_rate_segments(segs, CFG)
    blocks = (traces.expand(segs) // CFG.block_bytes).astype(np.int32)
    assert abs(hr_seg - hit_rate(blocks, CFG)) < 1e-9


def test_network_trace_burst_accounting():
    stream_bursts = traces.total_bursts(traces.network_trace())
    # every AccelOp's traffic appears, to burst rounding, in the trace
    from repro.core.runtime import compile_network
    traffic = sum(op.total_traffic for op in compile_network().accel_ops)
    assert 0 <= stream_bursts - traffic // traces.BURST_BYTES < 10_000


def test_interleave_preserves_bursts_and_content():
    segs = traces.network_trace(max_ops=4)
    inter = traces.interleave(segs, 64)
    assert traces.total_bursts(inter) == traces.total_bursts(segs)
    assert sorted(traces.expand(inter).tolist()) == \
        sorted(traces.expand(segs).tolist())
    assert max(s.count for s in inter) <= 64


def test_window_clips_exactly():
    segs = traces.network_trace(max_ops=4)
    win = traces.window(segs, 12_345)
    assert traces.total_bursts(win) == 12_345
    np.testing.assert_array_equal(traces.expand(win),
                                  traces.expand(segs)[:12_345])


def test_window_drops_zero_count_segments():
    """Windowing at an exact chunk boundary (or over an already-empty
    input segment) must drop the degenerate record, not emit a count-0
    segment that expands to an empty array."""
    segs = [Segment(0, 32, 100), Segment(9999, 32, 0),
            Segment(1 << 16, 32, 100)]
    # clip lands exactly on the first segment's boundary
    win = traces.window(segs, 100)
    assert [s.count for s in win] == [100]
    # the zero-count input segment disappears, the clip still lands
    win = traces.window(segs, 150)
    assert [s.count for s in win] == [100, 50]
    assert all(s.count > 0 for s in win)
    assert len(traces.expand(win)) == 150
    assert as_address_array(traces.expand(win)).shape == (150,)


def test_split_never_emits_zero_count_chunks():
    assert Segment(0, 32, 0).split(16) == []
    chunks = Segment(0, 32, 48).split(16)
    assert [c.count for c in chunks] == [16, 16, 16]
    assert Segment(64, 32, 1).split(16)[0].count == 1


def test_per_segment_hits_and_miss_runs():
    segs = [Segment(0, 32, 3000), Segment(0, 32, 500),
            Segment(1 << 18, 32, 64), Segment(5000, 256, 100)]
    res = simulate_segments(segs, CFG, per_segment=True,
                            collect_miss_runs=True)
    blocks = (traces.expand(segs) // CFG.block_bytes).astype(np.int32)
    _, bits = _scan_trace(cold_state(CFG.sets, CFG.ways),
                          jnp.asarray(blocks), sets=CFG.sets,
                          ways=CFG.ways)
    bits = np.asarray(bits)
    o, ref = 0, []
    for s in segs:
        ref.append(int(bits[o:o + s.count].sum()))
        o += s.count
    assert res.per_segment_hits.tolist() == ref
    # miss runs expand to exactly the missed blocks, in access order
    got = np.concatenate([np.arange(n) + b for b, n, _ in res.miss_runs])
    np.testing.assert_array_equal(got, blocks[~bits])
    assert all(0 <= idx < len(segs) for _, _, idx in res.miss_runs)


def test_network_op_segments_flatten_to_network_trace():
    per_op = traces.network_op_segments(max_ops=6)
    flat = [s for segs in per_op for s in segs]
    assert flat == traces.network_trace(max_ops=6)
    assert all(s.stream in ("weight", "ifmap", "ofmap")
               for segs in per_op for s in segs)


def test_warm_initial_state_disables_closed_form():
    # a passed-in state may hold anything: the engine must not assume
    # segment disjointness it can only prove within one call
    warm = simulate_segments([Segment(0, 32, 4096)], CFG)
    seg2 = [Segment(0, 64, CFG.sets * CFG.ways)]   # re-reads resident blocks
    res = simulate_segments(seg2, CFG, state=warm.state)
    blocks1 = (traces.expand([Segment(0, 32, 4096)])
               // CFG.block_bytes).astype(np.int32)
    blocks2 = (traces.expand(seg2) // CFG.block_bytes).astype(np.int32)
    both = np.concatenate([blocks1, blocks2])
    state, hits = _scan_trace(cold_state(CFG.sets, CFG.ways),
                              jnp.asarray(both), sets=CFG.sets,
                              ways=CFG.ways)
    assert warm.hits + res.hits == int(hits.sum())
    np.testing.assert_array_equal(np.asarray(res.state[0]),
                                  np.asarray(state[0]))
    np.testing.assert_array_equal(np.asarray(res.state[1]),
                                  np.asarray(state[1]))


def test_zero_stride_rejected():
    with pytest.raises(ValueError, match="stride"):
        simulate_segments([Segment(0, 0, 10)], CFG)


def test_segment_constructor_validation():
    with pytest.raises(ValueError, match="count"):
        Segment(0, 32, -5)
    with pytest.raises(ValueError, match="stride"):
        Segment(0, -32, 10)
    with pytest.raises(ValueError, match="base"):
        Segment(-64, 32, 10)
    # zero-count padding segments stay constructible with any base/stride
    assert Segment(0, 32, 0).count == 0


def test_segment_rejects_address_overflow():
    from repro.core.traces import DRAM_ADDR_BITS

    top = 1 << DRAM_ADDR_BITS
    with pytest.raises(ValueError, match="address space"):
        Segment(top, 32, 1)
    with pytest.raises(ValueError, match="address space"):
        Segment(top - 32, 64, 2)       # last access crosses the limit
    # the highest representable burst is fine
    assert Segment(top - 32, 32, 1).count == 1


def test_tuple_segments_bypass_unchanged():
    """Raw (base, stride, count) tuples are still accepted by the
    engines (the hypothesis strategies build them) — constructor
    validation applies to ``Segment`` objects only."""
    res = simulate_segments([(0, 32, 64)], CFG)
    assert res.accesses == 64


def test_address_array_guards_overflow():
    small = as_address_array([0, 1 << 20])
    assert small.dtype in (jnp.int32, jnp.int64)
    if not x64_enabled():
        with pytest.raises(OverflowError):
            as_address_array([1 << 40])
