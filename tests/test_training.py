"""Training substrate tests: convergence, microbatching, compression."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.synthetic import SyntheticStream, make_batch
from repro.models import init_params
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.compress import compressed_reduce, dequantize, quantize
from repro.types import param_values


def _setup(arch="qwen2-0.5b", batch=4, seq=32):
    cfg = get_smoke_config(arch)
    params = param_values(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params, batch, seq


def test_loss_decreases():
    cfg, params, b, s = _setup()
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=100)
    step_fn = jax.jit(make_train_step(cfg, opt))
    state = init_train_state(params)
    stream = SyntheticStream(cfg, b, s, seed=0)
    losses = []
    for i in range(30):
        state, m = step_fn(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.25, f"no learning: {first:.3f} -> {last:.3f}"


def test_microbatch_equivalence():
    """Grad accumulation over 2 microbatches == single-shot (fp32 tolerance)."""
    cfg, params, b, s = _setup(batch=4)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    batch = make_batch(cfg, b, s, seed=3)
    s1 = init_train_state(params)
    s2 = init_train_state(params)
    f1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    f2 = jax.jit(make_train_step(cfg, opt, microbatches=2))
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    # parameters after one update must agree closely
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, c in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_quantize_roundtrip_error_bound():
    key = jax.random.PRNGKey(7)
    g = jax.random.normal(key, (257, 33)) * 0.01
    q, scale = quantize(g)
    err = np.abs(np.asarray(dequantize(q, scale) - g))
    assert err.max() <= float(scale) / 2 + 1e-9


def test_error_feedback_accumulates():
    """With EF, the *sum* of compressed grads tracks the sum of true grads."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros((64,))
    comp_sum = jnp.zeros((64,))
    ef = {"g": jnp.zeros((64,))}
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.1
        out, ef = compressed_reduce({"g": g}, ef, axis="pod")
        true_sum = true_sum + g
        comp_sum = comp_sum + out["g"]
    # residual is bounded by one quantization step, not O(n_steps)
    resid = np.abs(np.asarray(comp_sum - true_sum))
    assert resid.max() < 0.05


def test_compressed_training_still_learns():
    cfg, params, b, s = _setup()
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=100)
    step_fn = jax.jit(make_train_step(cfg, opt, compress_axis="pod"))
    state = init_train_state(params, compress=True)
    stream = SyntheticStream(cfg, b, s, seed=0)
    losses = []
    for i in range(30):
        state, m = step_fn(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25


def test_data_stream_host_sharding_consistent():
    cfg = get_smoke_config("qwen2-0.5b")
    full = SyntheticStream(cfg, 8, 16, seed=5).batch_at(3)
    parts = [SyntheticStream(cfg, 8, 16, seed=5, num_hosts=4, host_id=h).batch_at(3)
             for h in range(4)]
    merged = jnp.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(np.asarray(full["tokens"]), np.asarray(merged))
