"""Closed-form DRAM row model: bit-exact parity with the per-access
open-row scan on stride-run segments — fixed cases, the real YOLOv3
DBB stream, and (when Hypothesis is installed) randomized segment
lists covering warm carry, wraparound revisits, and sparse strides."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traces
from repro.core.dram import DRAMConfig, access_latencies, segment_row_hits
from repro.core.traces import Segment


def _ref_row_hits(segments, cfg: DRAMConfig) -> int:
    lats = np.asarray(access_latencies(
        jnp.asarray(traces.expand(segments)), banks=cfg.banks,
        row_bytes=cfg.row_bytes, t_cas=cfg.t_cas_cycles,
        t_rcd=cfg.t_rcd_cycles, t_rp=cfg.t_rp_cycles))
    return int((lats == cfg.t_cas_cycles).sum())


def _assert_parity(segments, cfg):
    res = segment_row_hits(segments, cfg)
    assert res.row_hits == _ref_row_hits(segments, cfg)
    assert res.accesses == traces.total_bursts(segments)
    assert res.per_segment.sum() == res.row_hits
    return res


def test_sequential_stream_rows():
    cfg = DRAMConfig()
    res = _assert_parity([Segment(0, 32, 4096)], cfg)
    # 64 accesses per 2 KiB row, first of each row activates
    assert res.row_hits == 4096 - 4096 * 32 // cfg.row_bytes


def test_carry_across_segment_boundary():
    cfg = DRAMConfig()
    # second segment continues the same row: its first access must hit,
    # so the only activation in 16 accesses is the very first one
    segs = [Segment(0, 32, 8), Segment(256, 32, 8)]
    res = segment_row_hits(segs, cfg)
    assert res.row_hits == 15 == _ref_row_hits(segs, cfg)


def test_warm_revisit_and_disjoint_banks():
    cfg = DRAMConfig(banks=8, row_bytes=512)
    segs = [Segment(0, 32, 500), Segment(1 << 20, 64, 300),
            Segment(0, 32, 500), Segment(128, 32, 4)]
    _assert_parity(segs, cfg)


def test_sparse_stride_fallback():
    cfg = DRAMConfig(banks=8, row_bytes=512)
    # stride > row_bytes: gappy rows, replayed exactly
    _assert_parity([Segment(0, 4096, 100), Segment(17, 640, 333)], cfg)


def test_unaligned_bases():
    cfg = DRAMConfig(banks=4, row_bytes=256)
    _assert_parity([Segment(191, 48, 777), Segment(13, 96, 201)], cfg)


def test_open_rows_state_continuation():
    cfg = DRAMConfig(banks=8, row_bytes=512)
    a = [Segment(0, 32, 1000)]
    b = [Segment(16000, 32, 1000)]
    r1 = segment_row_hits(a, cfg)
    r2 = segment_row_hits(b, cfg, open_rows=r1.open_rows)
    assert r1.row_hits + r2.row_hits == _ref_row_hits(a + b, cfg)


def test_yolov3_stream_window_exact():
    cfg = DRAMConfig()
    segs = traces.window(traces.network_trace(max_ops=8), 200_000)
    _assert_parity(segs, cfg)


@pytest.mark.slow
def test_yolov3_full_frame_exact():
    cfg = DRAMConfig()
    segs = traces.network_trace()
    res = segment_row_hits(segs, cfg)
    assert res.row_hits == _ref_row_hits(segs, cfg)


# --------------------------------------------------------------------------
# segment-native pipeline totals (LLC + DRAM, no per-access replay)
# --------------------------------------------------------------------------
def _assert_pipeline_parity(segs, llc, dram=None):
    from repro.core.socsim import simulate_dbb_segments, simulate_dbb_stream

    got = simulate_dbb_segments(segs, llc=llc, dram=dram)
    ref = simulate_dbb_stream(traces.expand(segs), llc=llc, dram=dram)
    assert got.total_cycles == int(ref.total_cycles)
    lats = np.asarray(ref.latencies)
    assert got.llc_hits == int((lats == 20).sum())
    return got


def test_pipeline_totals_interleaved_window():
    from repro.core.cache import LLCConfig

    _assert_pipeline_parity(
        traces.default_dbb_window(max_bursts=1500, chunk_bursts=16),
        LLCConfig(size_bytes=4096, ways=4, block_bytes=64))


def test_pipeline_totals_warm_restream():
    from repro.core.cache import LLCConfig

    segs = [Segment(0, 32, 9000), Segment(0, 32, 9000),
            Segment(1 << 20, 32, 200)]
    _assert_pipeline_parity(
        segs, LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64),
        DRAMConfig(banks=8, row_bytes=1024))


def test_pipeline_totals_network_prefix():
    from repro.core.cache import LLCConfig

    segs = traces.window(traces.network_trace(max_ops=4), 40_000)
    got = _assert_pipeline_parity(
        segs, LLCConfig(size_bytes=256 * 1024, ways=8, block_bytes=64))
    assert 0.0 < got.llc_hit_rate < 1.0


def test_pipeline_rejects_row_straddling_blocks():
    from repro.core.cache import LLCConfig
    from repro.core.socsim import simulate_dbb_segments

    with pytest.raises(ValueError, match="row_bytes"):
        simulate_dbb_segments([Segment(0, 32, 64)],
                              llc=LLCConfig(size_bytes=4096, ways=4,
                                            block_bytes=96))


def test_property_random_segment_lists():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg = DRAMConfig(banks=4, row_bytes=256)
    seg_st = st.tuples(st.integers(0, 1 << 16),
                       st.integers(1, 512),
                       st.integers(0, 200))

    @given(st.lists(seg_st, max_size=8))
    @settings(max_examples=60, deadline=None)
    def check(metas):
        segs = [Segment(b, s, c) for b, s, c in metas]
        res = segment_row_hits(segs, cfg)
        assert res.row_hits == _ref_row_hits(segs, cfg)

    check()
