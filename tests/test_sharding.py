"""Sharding resolver: divisibility fallback, axis-conflict handling."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import activate_rules, spec_for
from repro.types import Param


def _mesh2x2():
    if len(jax.devices()) >= 4:
        devs = np.asarray(jax.devices()[:4]).reshape(2, 2)
    else:  # single-CPU test env: 1x1 mesh, same resolution logic
        devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def test_divisible_dims_shard():
    mesh = _mesh2x2()
    dp = mesh.devices.shape[0]
    with activate_rules(mesh):
        spec = spec_for((8, 16), ("embed", "mlp"))
        assert spec == P("data", "model") or spec == P("data",) or spec == P()


def test_indivisible_dim_drops_axis():
    mesh = _mesh2x2()
    with activate_rules(mesh) as rules:
        # 7 is not divisible by any axis size > 1 -> dropped, recorded
        spec = spec_for((7, 16), ("heads", "mlp"))
        if mesh.devices.shape[1] > 1:
            assert spec[0] is None
            assert any(d[0] == "heads" for d in rules.dropped)


def test_axis_used_once_per_array():
    """Two dims mapping to the same mesh axis: only the first gets it."""
    mesh = _mesh2x2()
    with activate_rules(mesh):
        spec = spec_for((16, 16), ("mlp", "heads"))  # both -> model
        if mesh.devices.shape[1] > 1:
            assert spec[0] == "model"
            assert len(spec) < 2 or spec[1] is None


def test_overrides_win():
    mesh = _mesh2x2()
    with activate_rules(mesh, {"act_seq": ("model",)}):
        spec = spec_for((4, 16, 8), ("act_batch", "act_seq", "act_embed"))
        if mesh.devices.shape[1] > 1:
            assert spec[1] == "model"


def test_multi_axis_composition():
    """A logical axis listing several mesh axes composes them in order."""
    mesh = _mesh2x2()
    total = mesh.devices.size
    with activate_rules(mesh, {"act_batch": ("data", "model")}):
        spec = spec_for((total * 2,), ("act_batch",))
        if total > 1:
            assert spec == P(("data", "model"))


def test_param_trees_resolve():
    from repro.sharding import param_shardings

    mesh = _mesh2x2()
    with activate_rules(mesh):
        tree = {"w": Param(jnp.zeros((8, 16)), ("embed", "mlp")),
                "b": Param(jnp.zeros((16,)), ("mlp",))}
        sh = param_shardings(tree)
        assert sh["w"].mesh.shape == dict(zip(mesh.axis_names,
                                              mesh.devices.shape))
