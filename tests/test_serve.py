"""Serving engine: batched generate with EOS masking."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.synthetic import make_batch
from repro.models import init_params
from repro.serve import ServeEngine
from repro.types import param_values


def test_generate_batched():
    cfg = get_smoke_config("qwen2-0.5b")
    params = param_values(init_params(jax.random.PRNGKey(0), cfg))
    batch = make_batch(cfg, 3, 16, seed=0)
    batch.pop("labels")
    eng = ServeEngine(cfg, params, cache_len=64, eos_id=0, temperature=0.0)
    res = eng.generate(batch, max_new=8)
    assert res.tokens.shape[0] == 3
    assert res.tokens.shape[1] <= 8
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()
    # greedy decode is deterministic
    res2 = eng.generate(batch, max_new=8)
    np.testing.assert_array_equal(res.tokens, res2.tokens)


def test_generate_hybrid_and_ssm():
    for arch in ("mamba2-130m", "recurrentgemma-9b"):
        cfg = get_smoke_config(arch)
        params = param_values(init_params(jax.random.PRNGKey(1), cfg))
        batch = make_batch(cfg, 2, 16, seed=1)
        batch.pop("labels")
        eng = ServeEngine(cfg, params, cache_len=64, eos_id=0)
        res = eng.generate(batch, max_new=4)
        assert res.tokens.shape[0] == 2
