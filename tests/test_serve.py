"""Serving engine: continuous batching, the deprecated ``generate()``
shim's bit-exact parity with the seed loop, scheduler determinism, and
the typed record surfaces."""
from __future__ import annotations

import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.synthetic import make_batch
from repro.models import decode_step, init_params, prefill
from repro.serve import (
    EngineStats,
    Request,
    ServeEngine,
    SoCLatencyOracle,
    StepResult,
)
from repro.types import param_values


def _setup(arch="qwen2-0.5b", seed=0):
    cfg = get_smoke_config(arch)
    params = param_values(init_params(jax.random.PRNGKey(seed), cfg))
    return cfg, params


def _seed_reference_generate(cfg, params, batch, max_new, cache_len,
                             eos_id):
    """The seed's padded static-batch greedy loop, inlined: batched
    prefill, then full-batch ``decode_step`` with EOS masking.  The
    engine shim must reproduce these tokens bit-exactly."""
    v = cfg.vocab_size
    logits, caches, t = prefill(params, batch, cfg, cache_len)
    tok = np.asarray([int(np.argmax(np.asarray(r)[:v])) for r in logits],
                     np.int32)
    done = tok == eos_id
    out = [tok.copy()]
    for _ in range(max_new - 1):
        if done.all():
            break
        logits, caches = decode_step(params, caches, tok[:, None], t, cfg)
        t = t + 1
        tok = np.asarray(
            [int(np.argmax(np.asarray(r)[:v])) for r in logits], np.int32)
        tok = np.where(done, eos_id, tok)
        out.append(tok.copy())
        done |= tok == eos_id
    toks = np.stack(out, axis=1)
    lengths = np.argmax(toks == eos_id, axis=1)
    lengths = np.where((toks == eos_id).any(axis=1), lengths,
                       toks.shape[1])
    return toks, lengths


# --------------------------------------------------------------------------
# deprecated shim: seed parity
# --------------------------------------------------------------------------
def test_generate_shim_matches_seed_loop_bit_exact():
    cfg, params = _setup()
    batch = make_batch(cfg, 3, 16, seed=0)
    batch.pop("labels")
    ref_toks, ref_lens = _seed_reference_generate(
        cfg, params, batch, max_new=8, cache_len=64, eos_id=0)
    eng = ServeEngine(cfg, params, cache_len=64, eos_id=0)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        res = eng.generate(batch, max_new=8)
    np.testing.assert_array_equal(res.tokens, ref_toks)
    np.testing.assert_array_equal(res.lengths, ref_lens)


def test_generate_shim_parity_with_queueing():
    """max_slots below the batch size forces the shim's requests through
    queued continuous batching — greedy rows are batch-size invariant,
    so tokens must still match the padded static-batch loop."""
    cfg, params = _setup()
    batch = make_batch(cfg, 4, 16, seed=2)
    batch.pop("labels")
    ref_toks, _ = _seed_reference_generate(
        cfg, params, batch, max_new=6, cache_len=64, eos_id=0)
    eng = ServeEngine(cfg, params, cache_len=64, max_slots=2, eos_id=0)
    with pytest.warns(DeprecationWarning):
        res = eng.generate(batch, max_new=6)
    np.testing.assert_array_equal(res.tokens, ref_toks)


def test_generate_hybrid_ssm_and_encoder_decoder():
    """The shim (and the extras path for whisper's frames) works across
    cache families: attention KV, SSM state, recurrent hybrid."""
    for arch in ("mamba2-130m", "recurrentgemma-9b", "whisper-tiny"):
        cfg = get_smoke_config(arch)
        params = param_values(init_params(jax.random.PRNGKey(1), cfg))
        batch = make_batch(cfg, 2, 16, seed=1)
        batch.pop("labels")
        eng = ServeEngine(cfg, params, cache_len=64, eos_id=0)
        with pytest.warns(DeprecationWarning):
            res = eng.generate(batch, max_new=4)
        assert res.tokens.shape[0] == 2
        assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()


# --------------------------------------------------------------------------
# continuous batching
# --------------------------------------------------------------------------
def _requests(cfg, n, prompt_len=12, max_new=6, gap_s=0.0, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=tuple(int(x) for x in
                                 rng.integers(3, cfg.vocab_size, prompt_len)),
                    max_new=max_new, arrival_s=i * gap_s)
            for i in range(n)]


def test_continuous_batching_over_limited_slots():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, cache_len=32, max_slots=2, eos_id=0)
    for r in _requests(cfg, 5):
        eng.submit(r)
    stats = eng.run()
    assert stats.requests == 5
    assert stats.max_occupancy == 2          # never exceeds the slots
    assert {f["rid"] for f in eng.finished} == set(range(5))
    # admission interleaves with decode: some steps must be mixed or a
    # later prefill lands while earlier requests are mid-decode
    kinds = [r.kind for r in eng.step_log]
    assert kinds[0] == "prefill"
    assert any(k in ("mixed", "prefill") for k in kinds[1:])
    assert stats.sim_time_s > 0 and stats.tokens_per_s > 0
    # the pool drained cleanly
    eng.kv.check_partition()
    assert eng.kv.free_blocks == eng.kv.num_blocks
    # the clock is the oracle's, monotone across the log
    times = [r.sim_time_s for r in eng.step_log]
    assert all(b >= a for a, b in zip(times, times[1:]))


def test_occupancy_degrades_llc_hit_rate():
    """The Fig. 6 serving-side effect: with the LLC sized to ~cover the
    weights, each co-resident request's KV stream grows the cyclic
    working set and the steady-state decode hit rate drops."""
    from repro.core.cache import LLCConfig
    from repro.models import decode_working_set

    cfg, params = _setup()
    ws = decode_working_set(cfg)
    llc = LLCConfig(size_bytes=-(-ws.weight_bytes // 512) * 512 + 4096,
                    ways=8, block_bytes=64)

    def min_decode_hit(n_req):
        eng = ServeEngine(cfg, params, cache_len=64, max_slots=8, eos_id=0,
                          oracle=SoCLatencyOracle(ws, llc=llc))
        for r in _requests(cfg, n_req, prompt_len=20, max_new=16):
            eng.submit(r)
        eng.run()
        hits = [r.llc_hit_rate for r in eng.step_log
                if r.kind == "decode" and r.llc_hit_rate is not None]
        return min(hits)

    assert min_decode_hit(6) < min_decode_hit(1)


def test_idle_step_fast_forwards_to_arrival():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, cache_len=32, eos_id=0)
    eng.submit(Request(rid=0, tokens=(5, 6, 7), max_new=2,
                       arrival_s=1e-3))
    first = eng.step()
    assert first.kind == "idle"
    assert eng.clock_s >= 1e-3
    eng.run()
    assert eng.stats().requests == 1
    assert eng.stats().idle_steps == 1


def test_submit_validation():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, cache_len=16, eos_id=0)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng.submit(Request(rid=0, tokens=tuple(range(3, 15)), max_new=8))
    eng.submit(Request(rid=1, tokens=(3, 4, 5), max_new=4))
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(Request(rid=1, tokens=(3, 4), max_new=2))
    with pytest.raises(ValueError, match="at least one prompt token"):
        Request(rid=2, tokens=(), max_new=2)
    with pytest.raises(ValueError, match="max_new"):
        Request(rid=2, tokens=(3,), max_new=0)


def test_keyword_only_engine_config():
    cfg, params = _setup()
    with pytest.raises(TypeError):
        ServeEngine(cfg, params, 64)         # cache_len must be keyword


# --------------------------------------------------------------------------
# determinism
# --------------------------------------------------------------------------
def _run_trace(cfg, params, n=4, seed=3):
    eng = ServeEngine(cfg, params, cache_len=32, max_slots=2, eos_id=0)
    for r in _requests(cfg, n, gap_s=2e-5, seed=seed):
        eng.submit(r)
    stats = eng.run()
    return eng, stats


def test_scheduler_determinism_across_runs():
    cfg, params = _setup()
    a, sa = _run_trace(cfg, params)
    b, sb = _run_trace(cfg, params)
    assert [f["tokens"] for f in a.finished] == \
           [f["tokens"] for f in b.finished]
    assert [r.cycles for r in a.step_log] == [r.cycles for r in b.step_log]
    assert sa == sb                          # frozen dataclass equality


def test_checkpoint_restore_resumes_bit_identical():
    cfg, params = _setup()
    ref, _ = _run_trace(cfg, params)

    eng = ServeEngine(cfg, params, cache_len=32, max_slots=2, eos_id=0)
    for r in _requests(cfg, 4, gap_s=2e-5, seed=3):
        eng.submit(r)
    for _ in range(5):
        eng.step()
    snap = eng.checkpoint()

    fresh = ServeEngine(cfg, params, cache_len=32, max_slots=2, eos_id=0)
    fresh.restore(snap)
    while fresh.queue or fresh._active_slot_ids():
        fresh.step()
    assert [f["tokens"] for f in fresh.finished] == \
           [f["tokens"] for f in ref.finished]
    assert ([r.cycles for r in fresh.step_log]
            == [r.cycles for r in ref.step_log[5:]])
    assert fresh.stats() == ref.stats()


def test_restore_rejects_mismatched_config():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, cache_len=32, eos_id=0)
    eng.submit(Request(rid=0, tokens=(3, 4, 5), max_new=2))
    snap = eng.checkpoint()
    other = ServeEngine(cfg, params, cache_len=64, eos_id=0)
    with pytest.raises(ValueError, match="fingerprint"):
        other.restore(snap)


# --------------------------------------------------------------------------
# typed records
# --------------------------------------------------------------------------
def test_request_record_round_trip():
    r = Request(rid=7, tokens=(3, 9, 4), max_new=5, arrival_s=0.25)
    back = Request.from_record(json.loads(json.dumps(r.to_record())))
    assert back == r


def test_step_result_and_stats_record_round_trips():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, cache_len=32, eos_id=0)
    for r in _requests(cfg, 2):
        eng.submit(r)
    stats = eng.run()
    for res in eng.step_log:
        back = StepResult.from_record(json.loads(json.dumps(
            res.to_record())))
        assert back == res
    back = EngineStats.from_record(json.loads(json.dumps(
        stats.to_record())))
    assert back == stats
    with pytest.raises(ValueError, match="unknown step kind"):
        StepResult(step=0, kind="bogus", cycles=1, sim_time_s=0.0,
                   active=0, admitted=(), emitted=(), finished=())
