"""Serving benchmark coverage: every oracle backend gets a load sweep.

The gap this pins: the NPU backend landed in the latency oracle but the
serving benchmark only swept NVDLA, so NPU serving regressions were
invisible.  The bench now derives its backend list from the oracle's
``SUPPORTED_BACKENDS`` — these tests fail if a new backend reaches the
oracle without reaching the bench, or if the bench's sweep loop stops
consuming the shared constant.
"""
from __future__ import annotations

import inspect

import pytest

from repro.serve.oracle import SUPPORTED_BACKENDS, SoCLatencyOracle


def test_oracle_supported_backends_is_exhaustive():
    assert SUPPORTED_BACKENDS == ("nvdla", "npu")
    with pytest.raises(ValueError, match="unknown backend"):
        from repro.configs import get_smoke_config
        from repro.models import decode_working_set

        SoCLatencyOracle(decode_working_set(get_smoke_config("qwen2-0.5b")),
                         backend="tpu")


def test_oracle_constructs_for_every_backend():
    from repro.configs import get_smoke_config
    from repro.models import decode_working_set

    ws = decode_working_set(get_smoke_config("qwen2-0.5b"))
    for backend in SUPPORTED_BACKENDS:
        oracle = SoCLatencyOracle(ws, backend=backend)
        assert oracle.backend == backend
        # each backend lowers a real weight stream for a 1-slot step
        segs = oracle._weight_segments(slots=1)
        assert segs and sum(s.count for s in segs) > 0


def test_bench_sweeps_every_supported_backend():
    serve_bench = pytest.importorskip(
        "benchmarks.serve_bench",
        reason="benchmarks package needs the repo root on sys.path")

    # the bench's backend list is the oracle's, by construction …
    assert serve_bench.BACKENDS == SUPPORTED_BACKENDS
    # … and the sweep loop actually iterates it (not a stale literal)
    src = inspect.getsource(serve_bench.run)
    assert "for backend in BACKENDS" in src
    assert inspect.signature(serve_bench._run_load_point).parameters[
        "backend"].default == "nvdla"
