"""Fig. 6 — NVDLA slowdown under BwWrite co-runners (WSS x #cores).

Driven by ``repro.core.sweep.sweep_interference``: the closed-form
slowdown curves (anchored against the paper) plus, per (WSS, cores),
exact NVDLA LLC hit rates from the vmapped segment-lane engine with the
co-runner write streams interleaved as compressed segments, and DRAM
row-hit rates from the closed-form row model over each lane's exact
miss runs.  The sim-driven rows then feed those measurements back into
``op_cycles``: the eviction probability and the extra row-activation
latency come from the simulated lanes, while bus queueing and bandwidth
share (invisible to a trace simulation) stay on the calibrated closed
form.
"""
from __future__ import annotations

import dataclasses

from repro.core.sweep import sweep_interference

PAPER = {("llc", 4): 2.1, ("dram", 4): 2.5}


def run(smoke: bool = False) -> list[tuple]:
    if smoke:
        sw = sweep_interference(corunners=(0, 2), window_bursts=512)
    else:
        sw = sweep_interference()
    rows = []
    for wss in ("l1", "llc", "dram"):
        for n, v in sorted(sw.slowdowns[wss].items()):
            paper = PAPER.get((wss, n))
            note = f"paper: {paper}" if paper else ""
            rows.append((f"fig6/{wss}_x{n}", round(v, 3), note))
    for (wss, n), hr in sorted(sw.sim_row_hit_rates.items()):
        rows.append((f"fig6/simrowhit_{wss}_x{n}", round(hr, 3),
                     "NVDLA DRAM row-hit rate, closed-form rows over "
                     "exact miss runs"))
    for (wss, n), hr in sorted(sw.sim_hit_rates.items()):
        rows.append((f"fig6/simllchit_{wss}_x{n}", round(hr, 3),
                     "NVDLA LLC hit rate, segment lanes"))
    if not smoke:
        rows.extend(_sim_driven_rows(sw))
    return rows


def _sim_driven_rows(sw) -> list[tuple]:
    """Slowdowns with the trace-measurable interference terms (LLC
    eviction, DRAM row-locality loss) taken from the simulated lanes."""
    from repro.core.accelerator import accel_time_s, op_stream_hit_rates
    from repro.core.interference import with_corunners
    from repro.core.runtime import compile_network
    from repro.core.soc import SoCConfig

    soc = SoCConfig()
    stream = compile_network(conv_buf_bytes=soc.accel.conv_buf_bytes)
    solo_rates = op_stream_hit_rates(stream, soc.mem)
    solo_s = accel_time_s(stream, acc=soc.accel, mem=soc.mem,
                          hit_rates=solo_rates)["seconds"]
    h0 = sw.sim_hit_rates[("l1", 0)]
    rh0 = sw.sim_row_hit_rates[("l1", 0)]
    t_act = soc.mem.dram.t_rp_cycles + soc.mem.dram.t_rcd_cycles
    rows = []
    for wss in ("llc", "dram"):
        for n in sorted(n for w, n in sw.sim_hit_rates if w == wss):
            mem = with_corunners(soc.mem, n, wss)
            evict = max(0.0, 1.0 - sw.sim_hit_rates[(wss, n)] / h0)
            extra = max(0.0, rh0 - sw.sim_row_hit_rates[(wss, n)]) * t_act
            mem = dataclasses.replace(mem, llc_eviction_prob=evict,
                                      extra_dram_latency=extra)
            t = accel_time_s(stream, acc=soc.accel, mem=mem,
                             hit_rates=solo_rates)["seconds"]
            paper = PAPER.get((wss, n))
            note = ("sim-driven eviction/row terms" +
                    (f", paper: {paper}" if paper else ""))
            rows.append((f"fig6/simdrv_{wss}_x{n}",
                         round(t / solo_s, 3), note))
    return rows
