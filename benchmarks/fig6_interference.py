"""Fig. 6 — NVDLA slowdown under BwWrite co-runners (WSS x #cores).

Driven by ``repro.core.sweep.sweep_interference``: the closed-form
slowdown curves (anchored against the paper) plus, per (WSS, cores),
simulated NVDLA LLC hit rates and DRAM row-hit rates with the co-runner
write streams physically interleaved into the trace — all lanes one
vmapped device program.
"""
from __future__ import annotations

from repro.core.sweep import sweep_interference

PAPER = {("llc", 4): 2.1, ("dram", 4): 2.5}


def run() -> list[tuple]:
    sw = sweep_interference()
    rows = []
    for wss in ("l1", "llc", "dram"):
        for n, v in sorted(sw[wss].items()):
            paper = PAPER.get((wss, n))
            note = f"paper: {paper}" if paper else ""
            rows.append((f"fig6/{wss}_x{n}", round(v, 3), note))
    for (wss, n), hr in sorted(sw["sim_row_hit_rates"].items()):
        rows.append((f"fig6/simrowhit_{wss}_x{n}", round(hr, 3),
                     "NVDLA DRAM row-hit rate, co-runners interleaved"))
    for (wss, n), hr in sorted(sw["sim_hit_rates"].items()):
        rows.append((f"fig6/simllchit_{wss}_x{n}", round(hr, 3),
                     "NVDLA LLC hit rate, co-runners interleaved"))
    return rows
