"""Fig. 6 — NVDLA slowdown under BwWrite co-runners (WSS x #cores)."""
from __future__ import annotations

from repro.core import interference_sweep

PAPER = {("llc", 4): 2.1, ("dram", 4): 2.5}


def run() -> list[tuple]:
    sw = interference_sweep()
    rows = []
    for wss in ("l1", "llc", "dram"):
        for n, v in sorted(sw[wss].items()):
            paper = PAPER.get((wss, n))
            note = f"paper: {paper}" if paper else ""
            rows.append((f"fig6/{wss}_x{n}", round(v, 3), note))
    return rows
