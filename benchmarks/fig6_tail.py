"""Fig. 6 extended to the tail — victim latency QoS under an SoC farm.

The base Fig. 6 sweep reports *mean* NVDLA slowdown under co-runner
counts; real QoS targets are quantiles.  This suite runs the
``repro.core.farm`` multi-node composition — victim DBB requests
through the cycle-token NoC switch plus the shared LLC/DRAM lane —
and reports the steady-state victim request-latency distribution
(p50 / p99 / WCET, nearest-rank) versus co-runner node count, with and
without LLC way partitioning.

The farm nodes model edge SoCs with a small shared LLC (256 KiB) so
co-runner traffic genuinely evicts the victim's cross-pass working
set; way partitioning (victim fenced into half the ways) protects that
reuse, recovering the memory half of the tail, while the NoC half
(egress saturation past offered load 1.0) is policy-free — exactly the
CAT-style story the suite quantifies.

Emits ``BENCH_noc.json`` (override with ``BENCH_NOC_JSON``) and
asserts the acceptance properties inline: p99 degrades superlinearly
in node count, partitioning strictly recovers p99 at max contention,
the solo-farm lane record is bit-identical to
``interference_lane_metrics``, and the token-bundle switch matches the
per-cycle reference on this suite's own schedules.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig
from repro.core.farm import (FarmConfig, farm_schedule, simulate_farm,
                             victim_window)
from repro.core.noc import NoCConfig, NoCSwitch, simulate_reference
from repro.core.sweep import MixConfig, interference_lane_metrics
from repro.utils.stats import latency_summary

# edge-node shared LLC: small enough that "llc"-sized co-runner working
# sets overflow the victim's ways without a partition (the smoke window
# is 4x shorter, so its LLC shrinks 4x to keep the per-set pressure)
LLC = LLCConfig(size_bytes=256 * 1024, ways=8, block_bytes=64)
LLC_SMOKE = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64)
WAY_MASK = 0x0F                     # victim keeps half the ways


def _summaries(counts, *, llc: LLCConfig, max_bursts: int,
               way_mask: int | None, dram: DRAMConfig) -> dict:
    out = {}
    for n in counts:
        res = simulate_farm(
            llc=llc, dram=dram,
            farm=FarmConfig(nodes=n, way_mask=way_mask),
            max_bursts=max_bursts)
        s = latency_summary(res.steady())
        s["noc_mean"] = float(res.noc_latency.mean())
        s["mem_mean"] = float(res.mem_latency.mean())
        s["host_steps"] = res.noc.host_steps
        out[n] = (s, res)
    return out


def _check_bundle_parity(counts, *, max_bursts: int) -> int:
    """The suite's own schedules through the token-bundle switch vs the
    per-cycle reference — every result array must be element-wise
    equal, for a bundle size that does not divide the horizon."""
    checked = 0
    requests = 2 * max_bursts // 16          # passes * chunks
    for n in counts:
        farm = FarmConfig(nodes=n)
        sched = farm_schedule(requests, farm)
        cfg = NoCConfig(ports=n + 2, link_latency=farm.link_latency)
        ref = simulate_reference(sched, cfg)
        for bundle in (1, 7, 64):
            got = NoCSwitch(cfg).simulate(sched, bundle_cycles=bundle)
            for f in ("deliver_cycle", "egress", "src", "latency"):
                if not np.array_equal(getattr(got, f), getattr(ref, f)):
                    raise AssertionError(
                        f"token-bundle switch (bundle={bundle}, n={n}) "
                        f"diverged from the per-cycle reference on {f}")
            checked += 1
    return checked


def run(smoke: bool = False) -> list[tuple]:
    dram = DRAMConfig()
    counts = (0, 1, 2) if smoke else (0, 1, 2, 4)
    max_bursts = 512 if smoke else 2048
    llc = LLC_SMOKE if smoke else LLC
    base = _summaries(counts, llc=llc, max_bursts=max_bursts,
                      way_mask=None, dram=dram)
    part = _summaries(counts, llc=llc, max_bursts=max_bursts,
                      way_mask=WAY_MASK, dram=dram)

    # acceptance: the tail degrades superlinearly with node count …
    nmax, mid = counts[-1], counts[len(counts) // 2]
    p99 = {n: base[n][0]["p99"] for n in counts}
    if not (p99[nmax] - p99[mid] > p99[mid] - p99[counts[0]]):
        raise AssertionError(
            f"victim p99 not superlinear in co-runner nodes: {p99}")
    # … way partitioning measurably recovers the victim's p99 …
    if not part[nmax][0]["p99"] < p99[nmax]:
        raise AssertionError(
            f"way partitioning did not recover p99 at n={nmax}: "
            f"{part[nmax][0]['p99']} vs {p99[nmax]}")
    # … the solo farm's lane record is exactly the Fig. 6 solo lane …
    solo = base[0][1]
    lane_segs = victim_window("nvdla", max_bursts=max_bursts) * 2
    ref = interference_lane_metrics(lane_segs, llc=llc, dram=dram,
                                    mix=MixConfig(0, "l1"))
    if solo.metrics != ref:
        raise AssertionError("solo farm lane diverged from "
                             "interference_lane_metrics")
    # … and the token-bundle switch is bit-identical to per-cycle.
    parity = _check_bundle_parity((counts[0], nmax),
                                  max_bursts=512 if smoke else 1024)

    rows = []
    for n in counts:
        for tag, res in (("", base), ("part_", part)):
            s = res[n][0]
            for k in ("p50", "p99", "wcet"):
                rows.append((f"fig6_tail/{tag}{k}_x{n}", round(s[k], 1),
                             "steady-state victim request cycles"))
        rows.append((f"fig6_tail/noc_mean_x{n}",
                     round(base[n][0]["noc_mean"], 1),
                     "switch queueing + link, all passes"))
    rows.append(("fig6_tail/bundle_parity_checks", parity,
                 "token-bundle vs per-cycle reference schedules"))

    payload = {
        "llc": {"size_bytes": llc.size_bytes, "ways": llc.ways,
                "block_bytes": llc.block_bytes},
        "way_mask": WAY_MASK,
        "max_bursts": max_bursts,
        "nodes": list(counts),
        "unpartitioned": {str(n): base[n][0] for n in counts},
        "partitioned": {str(n): part[n][0] for n in counts},
    }
    path = os.environ.get("BENCH_NOC_JSON", "BENCH_noc.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    rows.append(("fig6_tail/json", path, "QoS distributions"))
    return rows
