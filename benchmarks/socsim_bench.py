"""Sweep-engine benchmark: seed vs batched/compressed simulation.

Three before/after comparisons, all on the same inputs with parity
asserted (the fast paths are exact, not approximations):

* **accesses/sec** — exact per-access LLC scan vs the compressed
  segment engine on a real interleaved layer window;
* **sweep-points/sec** — a 16-point LLC geometry sweep, per-config
  scans (each geometry a fresh XLA specialization, as the seed ran it)
  vs one vmapped padded-geometry program;
* **FAME-1 replay** — the seed's fixed ``4*T*(n+1)`` host-cycle
  schedule vs the chunked early-exit scheduler, warm-program timings.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import traces
from repro.core.cache import (
    LLCConfig,
    simulate_segments,
    simulate_trace,
)
from repro.core.socsim import simulate_dbb_stream
from repro.core.sweep import (
    batched_hits,
    grid_configs,
    segment_sweep_hit_rates,
)
from repro.utils.env import jax_enable_x64


def _wall(fn, iters: int = 3) -> float:
    fn()                                     # warm: compile + caches
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def _bench_compressed(rows: list) -> None:
    cfg = LLCConfig(size_bytes=256 * 1024, ways=8, block_bytes=64)
    # stream granularity: whole weight/ifmap/ofmap streams in issue
    # order (what the Fig. 5 hit-rate replay consumes) ...
    streams = traces.window(traces.network_trace(max_ops=12), 400_000)
    # ... and arbiter granularity: 256-burst round-robin interleave
    fine = traces.window(traces.interleave(
        traces.network_trace(max_ops=12), 256), 400_000)

    for label, segs in (("stream", streams), ("interleaved", fine)):
        n = traces.total_bursts(segs)
        addrs = traces.expand(segs)
        blocks = jnp.asarray((addrs // cfg.block_bytes).astype(np.int32))

        def exact():
            return jax.block_until_ready(
                simulate_trace(blocks, sets=cfg.sets, ways=cfg.ways))

        def compressed():
            return simulate_segments(segs, cfg)

        t_exact = _wall(exact, iters=1)
        t_comp = _wall(compressed, iters=3)
        res = compressed()
        assert res.hits == int(np.asarray(exact()).sum()), "parity violation"
        rows.append((f"socsim/exact_scan_{label}_acc_per_s",
                     round(n / t_exact), ""))
        rows.append((f"socsim/compressed_{label}_acc_per_s",
                     round(n / t_comp),
                     f"{n} bursts, {len(segs)} segments"))
        rows.append((f"socsim/compressed_{label}_speedup_x",
                     round(t_exact / t_comp, 1),
                     "target >= 10x" if label == "stream" else
                     "fine-grain fallback path"))


def _bench_sweep(rows: list) -> None:
    cfgs = grid_configs((0.5, 8, 64, 1024), (32, 64, 128, 256))  # 16 points
    configs = list(cfgs.values())
    pts = len(configs)

    # the sweep: all 16 geometries over the full-frame DBB stream.  The
    # seed's exact per-access scan is linear in trace length, so it is
    # measured on a sub-window and extrapolated (a full-frame seed sweep
    # would run for minutes); the engine replays the whole frame.
    frame = traces.network_trace()
    n_frame = traces.total_bursts(frame)
    win = traces.window(frame, 400_000)
    n_win = traces.total_bursts(win)
    addrs = traces.expand(win)

    def seed_window():
        # the seed path: expand + one exact scan per geometry, each
        # (sets, ways) its own XLA specialization
        out = []
        for c in configs:
            blocks = jnp.asarray((addrs // c.block_bytes).astype(np.int32))
            out.append(float(jnp.mean(simulate_trace(
                blocks, sets=c.sets, ways=c.ways).astype(jnp.float32))))
        return out

    ref = seed_window()                      # also warms per-point compiles
    assert np.allclose(ref, segment_sweep_hit_rates(win, configs),
                       atol=1e-6), "sweep parity violation"
    t_seed_win = _wall(seed_window, iters=1)
    scale = n_frame / n_win
    t_seed_frame = t_seed_win * scale

    def engine_frame():
        return segment_sweep_hit_rates(frame, configs)

    t0 = time.perf_counter()
    engine_frame()
    t_engine_cold = time.perf_counter() - t0
    t_engine = _wall(engine_frame, iters=2)
    rows.append(("socsim/sweep_seed_pts_per_s",
                 round(pts / t_seed_frame, 3),
                 f"{pts}-point grid, {n_frame}-burst frame "
                 f"(measured on {n_win}, x{scale:.1f} linear)"))
    rows.append(("socsim/sweep_engine_pts_per_s", round(pts / t_engine, 2),
                 "compressed segment engine, full frame, warm"))
    rows.append(("socsim/sweep_speedup_x",
                 round(t_seed_frame / t_engine, 1), "target >= 10x"))
    rows.append(("socsim/sweep_speedup_cold_x",
                 round(t_seed_frame / t_engine_cold, 1),
                 "first sweep incl. engine compiles"))

    # -- vmapped per-access path (fine-interleaved windows, fig5/fig6) --
    win = traces.expand(traces.default_dbb_window(max_bursts=2048))

    def seed_window():
        out = []
        for c in configs:
            blocks = jnp.asarray((win // c.block_bytes).astype(np.int32))
            out.append(simulate_trace(blocks, sets=c.sets, ways=c.ways))
        return jax.block_until_ready(out)

    def batched():
        return jax.block_until_ready(batched_hits(win, configs))

    ref_w = seed_window()
    got_w = batched()
    for i in range(pts):
        assert np.array_equal(np.asarray(ref_w[i]), np.asarray(got_w[i])), i
    t_seed_w = _wall(seed_window)
    t_batched_w = _wall(batched)
    rows.append(("socsim/sweep_vmapped_warm_speedup_x",
                 round(t_seed_w / t_batched_w, 1),
                 "per-access bits, one vmapped program"))


def _bench_fame1(rows: list) -> None:
    llc = LLCConfig(size_bytes=4096, ways=4, block_bytes=64)
    addrs = traces.expand(traces.default_dbb_window(max_bursts=1024))

    def seed():
        return jax.block_until_ready(
            simulate_dbb_stream(addrs, llc, early_exit=False).latencies)

    def fast():
        return jax.block_until_ready(
            simulate_dbb_stream(addrs, llc, early_exit=True).latencies)

    assert np.array_equal(np.asarray(seed()), np.asarray(fast()))
    t_seed = _wall(seed)
    t_fast = _wall(fast)
    t = len(addrs)
    r_seed = simulate_dbb_stream(addrs, llc, early_exit=False)
    r_fast = simulate_dbb_stream(addrs, llc, early_exit=True)
    rows.append(("socsim/fame1_seed_acc_per_s", round(t / t_seed),
                 f"{r_seed.host_cycles} host cycles"))
    rows.append(("socsim/fame1_early_exit_acc_per_s", round(t / t_fast),
                 f"{r_fast.host_cycles} host cycles"))
    rows.append(("socsim/fame1_speedup_x", round(t_seed / t_fast, 1),
                 "target >= 3x"))


def run() -> list[tuple]:
    jax_enable_x64(False)   # defer to JAX_ENABLE_X64; addresses are checked
    rows: list[tuple] = []
    _bench_compressed(rows)
    _bench_sweep(rows)
    _bench_fame1(rows)
    return rows


if __name__ == "__main__":
    print("name,value,note")
    for row in run():
        print(",".join(str(x) for x in row))
