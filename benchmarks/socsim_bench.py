"""Sweep-engine benchmark: seed vs batched/compressed simulation.

Before/after comparisons, all on the same inputs with parity asserted
(the fast paths are exact, not approximations):

* **accesses/sec** — exact per-access LLC scan vs the compressed
  segment engine on a real interleaved layer window;
* **sweep-points/sec** — a 16-point LLC geometry sweep, per-config
  scans (each geometry a fresh XLA specialization, as the seed ran it)
  vs one vmapped padded-geometry program;
* **segment lanes** — the same 16-point sweep over the *full-frame*
  trace (no window cap): vmapped segment lanes vs the expanded-trace
  per-access batched path, bit-identical hit counts per lane;
* **segment-native socsim** — LLC+DRAM latency totals from segment
  arithmetic vs the per-access FAME-1 pipeline;
* **FAME-1 replay** — the seed's fixed ``4*T*(n+1)`` host-cycle
  schedule vs the chunked early-exit scheduler, warm-program timings.

Emits ``BENCH_sweep.json`` (override the path with ``BENCH_SWEEP_JSON``)
so CI can archive the perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import traces
from repro.core.cache import (
    LLCConfig,
    simulate_segments,
    simulate_trace,
)
from repro.core.socsim import simulate_dbb_segments, simulate_dbb_stream
from repro.core.sweep import (
    _batched_hits,
    grid_configs,
    segment_lane_hit_counts,
    segment_sweep_hit_rates,
)
from repro.utils.env import jax_enable_x64


def _wall(fn, iters: int = 3) -> float:
    fn()                                     # warm: compile + caches
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def _bench_compressed(rows: list, smoke: bool = False) -> None:
    cfg = LLCConfig(size_bytes=256 * 1024, ways=8, block_bytes=64)
    max_ops, clip = (4, 60_000) if smoke else (12, 400_000)
    # stream granularity: whole weight/ifmap/ofmap streams in issue
    # order (what the Fig. 5 hit-rate replay consumes) ...
    streams = traces.window(traces.network_trace(max_ops=max_ops), clip)
    # ... and arbiter granularity: 256-burst round-robin interleave
    fine = traces.window(traces.interleave(
        traces.network_trace(max_ops=max_ops), 256), clip)

    for label, segs in (("stream", streams), ("interleaved", fine)):
        n = traces.total_bursts(segs)
        addrs = traces.expand(segs)
        blocks = jnp.asarray((addrs // cfg.block_bytes).astype(np.int32))

        def exact():
            return jax.block_until_ready(
                simulate_trace(blocks, sets=cfg.sets, ways=cfg.ways))

        def compressed():
            return simulate_segments(segs, cfg)

        t_exact = _wall(exact, iters=1)
        t_comp = _wall(compressed, iters=3)
        res = compressed()
        assert res.hits == int(np.asarray(exact()).sum()), "parity violation"
        rows.append((f"socsim/exact_scan_{label}_acc_per_s",
                     round(n / t_exact), ""))
        rows.append((f"socsim/compressed_{label}_acc_per_s",
                     round(n / t_comp),
                     f"{n} bursts, {len(segs)} segments"))
        rows.append((f"socsim/compressed_{label}_speedup_x",
                     round(t_exact / t_comp, 1),
                     "target >= 10x" if label == "stream" else
                     "fine-grain fallback path"))


def _bench_sweep(rows: list, smoke: bool = False) -> None:
    if smoke:
        cfgs = grid_configs((8, 1024), (32, 128))                # 4 points
    else:
        cfgs = grid_configs((0.5, 8, 64, 1024), (32, 64, 128, 256))  # 16
    configs = list(cfgs.values())
    pts = len(configs)

    # the sweep: all geometries over the full-frame DBB stream.  The
    # seed's exact per-access scan is linear in trace length, so it is
    # measured on a sub-window and extrapolated (a full-frame seed sweep
    # would run for minutes); the engine replays the whole frame.
    frame = traces.network_trace(max_ops=8 if smoke else None)
    n_frame = traces.total_bursts(frame)
    win = traces.window(frame, 50_000 if smoke else 400_000)
    n_win = traces.total_bursts(win)
    addrs = traces.expand(win)

    def seed_window():
        # the seed path: expand + one exact scan per geometry, each
        # (sets, ways) its own XLA specialization
        out = []
        for c in configs:
            blocks = jnp.asarray((addrs // c.block_bytes).astype(np.int32))
            out.append(float(jnp.mean(simulate_trace(
                blocks, sets=c.sets, ways=c.ways).astype(jnp.float32))))
        return out

    ref = seed_window()                      # also warms per-point compiles
    assert np.allclose(ref, segment_sweep_hit_rates(win, configs),
                       atol=1e-6), "sweep parity violation"
    t_seed_win = _wall(seed_window, iters=1)
    scale = n_frame / n_win
    t_seed_frame = t_seed_win * scale

    def engine_frame():
        return segment_sweep_hit_rates(frame, configs)

    t0 = time.perf_counter()
    engine_frame()
    t_engine_cold = time.perf_counter() - t0
    t_engine = _wall(engine_frame, iters=2)
    rows.append(("socsim/sweep_seed_pts_per_s",
                 round(pts / t_seed_frame, 3),
                 f"{pts}-point grid, {n_frame}-burst frame "
                 f"(measured on {n_win}, x{scale:.1f} linear)"))
    rows.append(("socsim/sweep_engine_pts_per_s", round(pts / t_engine, 2),
                 "compressed segment engine, full frame, warm"))
    rows.append(("socsim/sweep_speedup_x",
                 round(t_seed_frame / t_engine, 1), "target >= 10x"))
    rows.append(("socsim/sweep_speedup_cold_x",
                 round(t_seed_frame / t_engine_cold, 1),
                 "first sweep incl. engine compiles"))

    # -- vmapped per-access path (fine-interleaved windows, fig5/fig6) --
    win = traces.expand(traces.default_dbb_window(max_bursts=2048))

    def seed_window():
        out = []
        for c in configs:
            blocks = jnp.asarray((win // c.block_bytes).astype(np.int32))
            out.append(simulate_trace(blocks, sets=c.sets, ways=c.ways))
        return jax.block_until_ready(out)

    def batched():
        # the private parity oracle — the public wrapper is deprecated
        return jax.block_until_ready(_batched_hits(win, configs))

    ref_w = seed_window()
    got_w = batched()
    for i in range(pts):
        assert np.array_equal(np.asarray(ref_w[i]), np.asarray(got_w[i])), i
    t_seed_w = _wall(seed_window)
    t_batched_w = _wall(batched)
    rows.append(("socsim/sweep_vmapped_warm_speedup_x",
                 round(t_seed_w / t_batched_w, 1),
                 "per-access bits, one vmapped program"))


def _bench_segment_lanes(rows: list, smoke: bool = False) -> None:
    """The tentpole comparison: a full-trace (no window cap) LLC
    geometry sweep through the vmapped segment-lane engine vs the
    expanded-trace per-access ``_batched_hits`` parity oracle — bit-identical hit
    counts per lane, wall-clock measured on the same grid."""
    if smoke:
        cfgs = grid_configs((8, 1024), (32, 128))
        frame = traces.network_trace(max_ops=8)
        probe_bursts = 20_000
    else:
        cfgs = grid_configs((0.5, 8, 64, 1024), (32, 64, 128, 256))
        frame = traces.network_trace()
        probe_bursts = 100_000
    configs = list(cfgs.values())
    pts = len(configs)
    n_frame = traces.total_bursts(frame)

    # parity: lane counts == per-access batched bits, per lane, on a
    # window where expansion is affordable
    probe = traces.window(frame, probe_bursts)
    addrs = traces.expand(probe)
    lane_counts = segment_lane_hit_counts(probe, configs).sum(axis=1)
    bit_counts = np.asarray(_batched_hits(addrs, configs)).sum(axis=1)
    assert np.array_equal(lane_counts, bit_counts), "lane parity violation"

    def expanded_probe():
        return jax.block_until_ready(_batched_hits(addrs, configs))

    t_probe = _wall(expanded_probe, iters=1)
    t_expanded = t_probe * (n_frame / len(addrs))    # linear in trace len

    def lanes_full():
        return segment_lane_hit_counts(frame, configs)

    t0 = time.perf_counter()
    lanes_full()
    t_lanes_cold = time.perf_counter() - t0
    t_lanes = _wall(lanes_full, iters=1)
    rows.append(("socsim/lanes_expanded_pts_per_s",
                 round(pts / t_expanded, 3),
                 f"{pts}-point grid, {n_frame}-burst frame (measured on "
                 f"{len(addrs)}, linear extrapolation)"))
    rows.append(("socsim/lanes_pts_per_s", round(pts / t_lanes, 2),
                 "segment lanes, full frame, warm"))
    rows.append(("socsim/lanes_speedup_x", round(t_expanded / t_lanes, 1),
                 "target >= 5x, bit-identical per-lane hit counts"))
    rows.append(("socsim/lanes_speedup_cold_x",
                 round(t_expanded / t_lanes_cold, 1),
                 "first sweep incl. lane-engine compiles"))
    rows.append(("socsim/lanes_acc_per_s", round(n_frame * pts / t_lanes),
                 "trace-accesses simulated per second across lanes"))


def _bench_segment_socsim(rows: list, smoke: bool = False) -> None:
    """Segment-native LLC+DRAM latency totals vs the per-access FAME-1
    pipeline (bit-identical totals)."""
    llc = LLCConfig(size_bytes=64 * 1024, ways=8, block_bytes=64)
    n = 2_000 if smoke else 8_000
    segs = traces.default_dbb_window(max_bursts=n, chunk_bursts=64)
    addrs = traces.expand(segs)

    def pipeline():
        return jax.block_until_ready(
            simulate_dbb_stream(addrs, llc=llc).latencies)

    def seg_native():
        return simulate_dbb_segments(segs, llc=llc)

    ref = simulate_dbb_stream(addrs, llc=llc)
    got = seg_native()
    assert int(ref.total_cycles) == got.total_cycles, "socsim parity"
    t_pipe = _wall(pipeline, iters=1)
    t_seg = _wall(seg_native, iters=3)
    rows.append(("socsim/pipeline_acc_per_s", round(n / t_pipe),
                 "per-access FAME-1 LLC+DRAM replay"))
    rows.append(("socsim/segment_totals_acc_per_s", round(n / t_seg),
                 "segment LLC engine + closed-form DRAM rows"))
    rows.append(("socsim/segment_totals_speedup_x",
                 round(t_pipe / t_seg, 1), "bit-identical totals"))


def _bench_fame1(rows: list, smoke: bool = False) -> None:
    llc = LLCConfig(size_bytes=4096, ways=4, block_bytes=64)
    addrs = traces.expand(traces.default_dbb_window(
        max_bursts=256 if smoke else 1024))

    def seed():
        return jax.block_until_ready(
            simulate_dbb_stream(addrs, llc=llc, early_exit=False).latencies)

    def fast():
        return jax.block_until_ready(
            simulate_dbb_stream(addrs, llc=llc, early_exit=True).latencies)

    assert np.array_equal(np.asarray(seed()), np.asarray(fast()))
    t_seed = _wall(seed)
    t_fast = _wall(fast)
    t = len(addrs)
    r_seed = simulate_dbb_stream(addrs, llc=llc, early_exit=False)
    r_fast = simulate_dbb_stream(addrs, llc=llc, early_exit=True)
    rows.append(("socsim/fame1_seed_acc_per_s", round(t / t_seed),
                 f"{r_seed.host_cycles} host cycles"))
    rows.append(("socsim/fame1_early_exit_acc_per_s", round(t / t_fast),
                 f"{r_fast.host_cycles} host cycles"))
    rows.append(("socsim/fame1_speedup_x", round(t_seed / t_fast, 1),
                 "target >= 3x"))


def _write_json(rows: list, smoke: bool) -> str:
    """BENCH_sweep.json: every row plus a headline block with the
    before/after accesses-per-sec and sweep-points/sec trajectory."""
    metrics = {name: {"value": value, "note": note}
               for name, value, note in rows}

    def val(name):
        m = metrics.get(name)
        return m["value"] if m else None

    doc = {
        "generated_by": "benchmarks/socsim_bench.py",
        "smoke": smoke,
        "headline": {
            "exact_scan_acc_per_s": val("socsim/exact_scan_stream_acc_per_s"),
            "compressed_acc_per_s": val("socsim/compressed_stream_acc_per_s"),
            "sweep_expanded_pts_per_s": val("socsim/lanes_expanded_pts_per_s"),
            "sweep_lanes_pts_per_s": val("socsim/lanes_pts_per_s"),
            "sweep_lanes_speedup_x": val("socsim/lanes_speedup_x"),
            "segment_totals_speedup_x": val("socsim/segment_totals_speedup_x"),
        },
        "metrics": metrics,
    }
    path = os.environ.get("BENCH_SWEEP_JSON", "BENCH_sweep.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    return path


def run(smoke: bool = False) -> list[tuple]:
    jax_enable_x64(False)   # defer to JAX_ENABLE_X64; addresses are checked
    rows: list[tuple] = []
    _bench_compressed(rows, smoke)
    _bench_sweep(rows, smoke)
    _bench_segment_lanes(rows, smoke)
    _bench_segment_socsim(rows, smoke)
    _bench_fame1(rows, smoke)
    path = _write_json(rows, smoke)
    rows.append(("socsim/bench_json", path, "machine-readable metrics"))
    return rows


if __name__ == "__main__":
    import sys

    print("name,value,note")
    for row in run(smoke="--smoke" in sys.argv):
        print(",".join(str(x) for x in row))
