"""Serving load sweep: tokens/s and tail latency in simulated SoC time.

The continuous-batching engine (``repro.serve``) is driven at several
offered loads (Poisson-free deterministic arrival gaps) against a fixed
request trace.  Every scheduler step is priced by the SoC latency
oracle — weight + paged-KV + state DBB traces through the exact
LLC/DRAM segment simulator — so throughput and p50/p99 request latency
come out in *simulated SoC seconds*, not host wall time.

The LLC is sized to cover the weight stream plus roughly two resident
requests' KV, so rising occupancy pushes the per-step working set past
capacity: decode hit rates fall and the latency tail grows with load —
the serving-side restatement of the paper's Fig. 6 co-runner
interference (each admitted request is a co-runner for the rest).

Asserts (acceptance criteria):

* >= 3 load points, each reporting tokens/s, p50 and p99 latency;
* p99 at the highest load exceeds p99 at the lightest load, and the
  worst decode-step LLC hit rate degrades with occupancy;
* the fixed request trace + seed is deterministic: two engine runs
  produce bit-identical tokens and per-step cycle counts.

The sweep runs once per oracle-supported accelerator backend
(``SUPPORTED_BACKENDS``: the NVDLA stream and the NPU's
weight-stationary re-streaming schedule) — tests/test_serve_bench.py
pins that the bench covers every backend the oracle speaks.

Emits ``BENCH_serve.json`` (override with ``BENCH_SERVE_JSON``) with
the full load-sweep curve per backend for CI archiving (``curves``;
``curve`` stays the NVDLA column for older tooling).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.serve.oracle import SUPPORTED_BACKENDS

# every backend the load sweep exercises — kept equal to the oracle's
# support set so a new backend cannot silently miss serving coverage
BACKENDS = SUPPORTED_BACKENDS


def _build_requests(cfg, n_req: int, prompt_len: int, max_new: int,
                    gap_s: float):
    from repro.serve import Request

    rng = np.random.default_rng(0)
    return [
        Request(rid=i,
                tokens=tuple(int(x) for x in
                             rng.integers(3, cfg.vocab_size, prompt_len)),
                max_new=max_new, arrival_s=i * gap_s)
        for i in range(n_req)
    ]


def _run_load_point(cfg, params, llc, *, cache_len: int, max_slots: int,
                    requests, backend: str = "nvdla") -> dict:
    from repro.models import decode_working_set
    from repro.serve import ServeEngine, SoCLatencyOracle

    oracle = SoCLatencyOracle(decode_working_set(cfg), llc=llc,
                              backend=backend)
    eng = ServeEngine(cfg, params, cache_len=cache_len,
                      max_slots=max_slots, eos_id=0, oracle=oracle)
    for r in requests:
        eng.submit(r)
    stats = eng.run()
    decode_hits = [r.llc_hit_rate for r in eng.step_log
                   if r.kind == "decode" and r.llc_hit_rate is not None]
    return {
        "stats": stats,
        "tokens": [list(f["tokens"]) for f in eng.finished],
        "cycles": [r.cycles for r in eng.step_log],
        "decode_hit_min": min(decode_hits) if decode_hits else 1.0,
    }


def run(smoke: bool = False) -> list[tuple]:
    import jax

    from repro.configs import get_smoke_config
    from repro.core.cache import LLCConfig
    from repro.models import decode_working_set, init_params
    from repro.types import param_values

    cfg = get_smoke_config("qwen2-0.5b")
    params = param_values(init_params(jax.random.PRNGKey(0), cfg))
    ws = decode_working_set(cfg)

    cache_len, max_slots = 64, 8
    n_req, prompt_len, max_new = (8, 20, 8) if smoke else (16, 20, 24)
    # LLC covers weights + ~2 resident requests' live KV: occupancy
    # beyond that spills the cyclic per-step working set (Fig. 6,
    # serving-side).  Set-modulo indexing accepts any ways*block
    # multiple, so the capacity cliff can sit exactly where we want it.
    live_kv = ws.kv_bytes(prompt_len + max_new)
    target = ws.weight_bytes + 2 * live_kv
    llc = LLCConfig(size_bytes=-(-target // 512) * 512,
                    ways=8, block_bytes=64)

    gaps = (3e-4, 1e-4, 1e-5) if smoke else (1e-3, 3e-4, 1e-4, 1e-5)
    rows: list[tuple] = []
    curves: dict[str, list] = {}
    t0 = time.time()
    for backend in BACKENDS:
        curve = curves.setdefault(backend, [])
        prefix = "serve" if backend == "nvdla" else f"serve/{backend}"
        for gap in gaps:
            reqs = _build_requests(cfg, n_req, prompt_len, max_new, gap)
            pt = _run_load_point(cfg, params, llc, cache_len=cache_len,
                                 max_slots=max_slots, requests=reqs,
                                 backend=backend)
            s = pt["stats"]
            load = 1.0 / gap
            curve.append({
                "offered_rps": load, "gap_s": gap,
                "tokens_per_s": s.tokens_per_s,
                "latency_p50_s": s.latency_p50_s,
                "latency_p99_s": s.latency_p99_s,
                "mean_occupancy": s.mean_occupancy,
                "max_occupancy": s.max_occupancy,
                "decode_hit_min": pt["decode_hit_min"],
                "sim_time_s": s.sim_time_s,
            })
            rows.append((f"{prefix}/tps@{load:.0f}rps",
                         f"{s.tokens_per_s:.0f}",
                         f"occ {s.mean_occupancy:.2f}"))
            rows.append((f"{prefix}/p50@{load:.0f}rps",
                         f"{s.latency_p50_s * 1e3:.3f}", "ms"))
            rows.append((f"{prefix}/p99@{load:.0f}rps",
                         f"{s.latency_p99_s * 1e3:.3f}", "ms"))

    # -- interference acceptance: the tail degrades with occupancy -------
    # (asserted per backend: every supported accelerator must reproduce
    # the occupancy-driven Fig. 6 effect, not just the NVDLA column)
    for backend, curve in curves.items():
        lo, hi = curve[0], curve[-1]
        assert hi["mean_occupancy"] > lo["mean_occupancy"], \
            f"{backend}: load sweep failed to raise occupancy"
        assert hi["latency_p99_s"] > lo["latency_p99_s"], \
            (f"{backend}: p99 did not degrade with load: "
             f"{lo['latency_p99_s']:.6f} -> {hi['latency_p99_s']:.6f}")
    curve = curves["nvdla"]
    lo, hi = curve[0], curve[-1]
    assert hi["decode_hit_min"] < lo["decode_hit_min"], \
        (f"decode LLC hit rate did not degrade with occupancy: "
         f"{lo['decode_hit_min']:.3f} -> {hi['decode_hit_min']:.3f}")
    rows.append(("serve/p99_degradation",
                 f"{hi['latency_p99_s'] / lo['latency_p99_s']:.2f}",
                 "x at max load"))

    # -- determinism acceptance: bit-identical tokens and latencies ------
    gap = gaps[1]
    reqs = _build_requests(cfg, n_req, prompt_len, max_new, gap)
    a = _run_load_point(cfg, params, llc, cache_len=cache_len,
                        max_slots=max_slots, requests=reqs)
    b = _run_load_point(cfg, params, llc, cache_len=cache_len,
                        max_slots=max_slots, requests=reqs)
    deterministic = a["tokens"] == b["tokens"] and a["cycles"] == b["cycles"]
    assert deterministic, "serving run is not reproducible"
    rows.append(("serve/deterministic", "1", "tokens+cycles bit-identical"))
    rows.append(("serve/wall_seconds", f"{time.time() - t0:.1f}", ""))

    path = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump({
            "arch": "qwen2-0.5b (smoke)",
            "cache_len": cache_len, "max_slots": max_slots,
            "n_requests": n_req, "prompt_len": prompt_len,
            "max_new": max_new,
            "llc_size_bytes": llc.size_bytes,
            "weight_bytes": ws.weight_bytes,
            "curve": curves["nvdla"],
            "curves": curves,
            "backends": list(BACKENDS),
            "deterministic": deterministic,
        }, f, indent=1)
    rows.append(("serve/json", path, "load-sweep curve"))
    return rows
