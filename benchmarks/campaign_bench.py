"""Campaign throughput: sequential vs mesh-sharded batched execution.

The tentpole before/after: the same campaign spec run twice through
``run_campaign`` — once strictly sequentially (``batch_points=1``, the
pre-mesh executor), once as vmapped lane batches sharded over a
``jax.sharding`` mesh of every visible device — with the resulting
manifests asserted byte-identical (the mesh path is exact, not an
approximation).  On a CPU host export
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` *before* Python
starts to get a 4-device mesh; with a single device the run still
measures the vmapped-batching win alone.

Full mode is the acceptance campaign: 64 points (16 same-``sets``
geometries x 4 co-runner mixes) on a 16384-burst window, target >= 5x
points/sec with 4 devices.  Smoke is 16 points on a 256-burst window.

Emits ``BENCH_campaign.json`` (override with ``BENCH_CAMPAIGN_JSON``)
so CI can archive the campaign-throughput trajectory.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time


def _acceptance_spec(points: int, window_bursts: int):
    from repro.campaign import (
        CampaignSpec,
        GeometrySpec,
        MixSpec,
        ModelSpec,
    )

    n_mixes = 4
    n_geoms = points // n_mixes
    # one vmap bucket (same set count) of low-associativity lanes:
    # ways x block combinations keep the padded way axis at 4 and every
    # co-runner span >= one chunk, so the lane programs stay dense
    sets = 16
    blocks = (128, 256, 512, 1024)
    geoms = tuple(GeometrySpec(size_kib=sets * w * b / 1024,
                               block=b, ways=w)
                  for b in blocks for w in range(1, n_geoms // len(blocks) + 1))
    mixes = (MixSpec(0, "l1"), MixSpec(1, "llc"),
             MixSpec(2, "llc"), MixSpec(2, "dram"))
    return CampaignSpec(
        name=f"bench-{points}pt",
        models=(ModelSpec(window_bursts=window_bursts),),
        geometries=geoms, mixes=mixes)


def run(smoke: bool = False) -> list[tuple]:
    import jax

    from repro.campaign import run_campaign
    from repro.launch.mesh import make_sweep_mesh

    points, window = (16, 256) if smoke else (64, 16384)
    spec = _acceptance_spec(points, window)
    mesh = make_sweep_mesh(jax.devices())
    n_dev = len(mesh.devices.ravel())

    def campaign(out_dir, **kw):
        t0 = time.perf_counter()
        res = run_campaign(spec, out_dir, **kw)
        dt = time.perf_counter() - t0
        assert res.completed == points and not res.failed, res.manifest
        return res, dt

    work = tempfile.mkdtemp(prefix="campaign_bench_")
    try:
        # warm the lane-engine compile caches so both sides time
        # simulation + journaling, not XLA compilation
        campaign(os.path.join(work, "warm_seq"), batch_points=1)
        campaign(os.path.join(work, "warm_mesh"), mesh=mesh,
                 batch_points=points)

        seq, t_seq = campaign(os.path.join(work, "seq"), batch_points=1)
        msh, t_mesh = campaign(os.path.join(work, "mesh"), mesh=mesh,
                               batch_points=points)
    finally:
        shutil.rmtree(work, ignore_errors=True)

    canon = lambda m: json.dumps(m, sort_keys=True)
    assert canon(seq.manifest) == canon(msh.manifest), \
        "mesh campaign manifest diverged from sequential"

    speedup = t_seq / t_mesh
    rows = [
        ("campaign/points", points, f"{window}-burst window"),
        ("campaign/devices", n_dev,
         "XLA_FLAGS=--xla_force_host_platform_device_count to widen"),
        ("campaign/seq_pts_per_s", round(points / t_seq, 2),
         "batch_points=1, journaled"),
        ("campaign/mesh_pts_per_s", round(points / t_mesh, 2),
         "vmapped lane batches over the device mesh, journaled"),
        ("campaign/mesh_speedup_x", round(speedup, 1),
         "target >= 5x at 4 devices, bit-identical manifests"
         if not smoke else "smoke grid"),
    ]

    doc = {
        "generated_by": "benchmarks/campaign_bench.py",
        "smoke": smoke,
        "points": points,
        "window_bursts": window,
        "devices": n_dev,
        "seq_pts_per_s": round(points / t_seq, 3),
        "mesh_pts_per_s": round(points / t_mesh, 3),
        "speedup_x": round(speedup, 2),
        "manifests_identical": True,
    }
    path = os.environ.get("BENCH_CAMPAIGN_JSON", "BENCH_campaign.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    rows.append(("campaign/bench_json", path, "machine-readable metrics"))
    return rows


if __name__ == "__main__":
    import sys

    print("name,value,note")
    for row in run(smoke="--smoke" in sys.argv):
        print(",".join(str(x) for x in row))
