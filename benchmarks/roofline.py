"""Roofline report: aggregates the dry-run artifacts into the per-cell
three-term table (EXPERIMENTS.md section Roofline)."""
from __future__ import annotations

import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__),
                         "../experiments/artifacts/dryrun")


def load_cells(mesh: str = "pod") -> list[dict]:
    d = os.path.join(ARTIFACTS, mesh)
    if not os.path.isdir(d):
        return []
    cells = []
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                cells.append(json.load(f))
    return cells


def run(smoke: bool = False) -> list[tuple]:
    rows = []
    for mesh in ("pod", "multipod"):
        cells = load_cells(mesh)
        n_ok = sum(1 for c in cells if "roofline" in c)
        n_skip = sum(1 for c in cells if "skipped" in c)
        n_err = sum(1 for c in cells if "error" in c)
        rows.append((f"roofline/{mesh}_cells_ok", n_ok, ""))
        rows.append((f"roofline/{mesh}_cells_skipped", n_skip, "documented"))
        rows.append((f"roofline/{mesh}_cells_failed", n_err, "must be 0"))
        for c in cells:
            if "roofline" not in c:
                continue
            r = c["roofline"]
            tag = f"roofline/{mesh}/{c['arch']}/{c['shape']}"
            rows.append((tag + "/dominant", r["dominant"], ""))
            rows.append((tag + "/compute_s", round(r["compute_s"], 5), ""))
            rows.append((tag + "/memory_s", round(r["memory_s"], 5), ""))
            rows.append((tag + "/collective_s", round(r["collective_s"], 5), ""))
            rows.append((tag + "/roofline_fraction",
                         round(r["roofline_fraction"], 4), ""))
    return rows


def table(mesh: str = "pod") -> str:
    """Markdown table for EXPERIMENTS.md."""
    cells = load_cells(mesh)
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | roofline frac | MODEL/HLO flops | HBM fit |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if "skipped" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                         f"skipped: {c['skipped'][:40]}… | — | — | — |")
            continue
        if "error" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | | | |")
            continue
        r = c["roofline"]
        mem = c.get("memory", {})
        temp = mem.get("temp_bytes", 0) / 2**30
        args = mem.get("argument_bytes", 0) / 2**30
        fit = "yes" if (temp + args) < 16 else f"NO ({temp + args:.0f}GiB)"
        ratio = c.get("model_flops_ratio")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{ratio:.2f} | {fit} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table("pod"))
