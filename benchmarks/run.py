"""Benchmark runner — one module per paper table/figure.

Prints ``name,value,note`` CSV.  ``python -m benchmarks.run [--only fig5]``.
``--smoke`` runs every suite on tiny grids (CI's benchmark job: proves
the drivers execute end to end and emits the ``BENCH_*.json`` artifacts
— sweep, campaign, serve, npu — without burning minutes of runner
time).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import campaign_bench, fig4_platforms, fig5_llc
from benchmarks import fig6_interference, fig6_tail, kernel_bench
from benchmarks import roofline, serve_bench, socsim_bench

SUITES = {
    "fig4": fig4_platforms.run,
    "fig5": fig5_llc.run,
    "fig6": fig6_interference.run,
    "fig6_tail": fig6_tail.run,
    "kernels": kernel_bench.run,
    "roofline": roofline.run,
    "socsim": socsim_bench.run,
    "campaign": campaign_bench.run,
    "serve": serve_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grids, assert JSON emission (CI)")
    args = ap.parse_args()
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    print("name,value,note")
    status: dict[str, tuple[bool, float, str]] = {}
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for row in fn(smoke=args.smoke):
                print(",".join(str(x) for x in row))
            status[name] = (True, time.time() - t0, "")
        except Exception as e:  # keep the remaining suites going
            status[name] = (False, time.time() - t0,
                            f"{type(e).__name__}: {e}")
            print(f"{name}/ERROR,{type(e).__name__},{e}", file=sys.stderr)
        print(f"_meta/{name}_seconds,{time.time()-t0:.1f},")
    json_notes = []
    if args.smoke and not args.only:
        contracts = (
            ("bench_json", "BENCH_SWEEP_JSON", "BENCH_sweep.json"),
            ("campaign_json", "BENCH_CAMPAIGN_JSON", "BENCH_campaign.json"),
            ("serve_json", "BENCH_SERVE_JSON", "BENCH_serve.json"),
            ("npu_json", "BENCH_NPU_JSON", "BENCH_npu.json"),
            ("noc_json", "BENCH_NOC_JSON", "BENCH_noc.json"),
        )
        for key, env, default in contracts:
            path = os.environ.get(env, default)
            try:
                with open(path) as f:   # smoke contract: JSON must exist
                    json.load(f)
                json_notes.append(f"_meta/{key},{path},valid")
            except (OSError, json.JSONDecodeError) as e:
                status[key] = (False, 0.0, f"{type(e).__name__}: {e}")
    # per-benchmark pass/fail summary — CI's log tail says exactly what
    # broke instead of silently archiving a partial BENCH_sweep.json
    print("== benchmark summary ==", file=sys.stderr)
    for name, (ok, secs, err) in status.items():
        line = (f"  {name:<10} {'PASS' if ok else 'FAIL':<4} {secs:6.1f}s"
                + (f"  {err}" if err else ""))
        print(line, file=sys.stderr)
    failed = [n for n, (ok, _, _) in status.items() if not ok]
    if failed:
        raise SystemExit(f"{len(failed)}/{len(status)} benchmark suites "
                         f"failed: {', '.join(failed)}")
    for note in json_notes:
        print(note)


if __name__ == "__main__":
    main()
