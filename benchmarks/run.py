"""Benchmark runner — one module per paper table/figure.

Prints ``name,value,note`` CSV.  ``python -m benchmarks.run [--only fig5]``.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import fig4_platforms, fig5_llc, fig6_interference
from benchmarks import kernel_bench, roofline, socsim_bench

SUITES = {
    "fig4": fig4_platforms.run,
    "fig5": fig5_llc.run,
    "fig6": fig6_interference.run,
    "kernels": kernel_bench.run,
    "roofline": roofline.run,
    "socsim": socsim_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES))
    args = ap.parse_args()
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    print("name,value,note")
    failed = 0
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for row in fn():
                print(",".join(str(x) for x in row))
        except Exception as e:  # keep the suite going, flag at exit
            failed += 1
            print(f"{name}/ERROR,{type(e).__name__},{e}", file=sys.stderr)
        print(f"_meta/{name}_seconds,{time.time()-t0:.1f},")
    if failed:
        raise SystemExit(f"{failed} suites failed")


if __name__ == "__main__":
    main()
