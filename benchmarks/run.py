"""Benchmark runner — one module per paper table/figure.

Prints ``name,value,note`` CSV.  ``python -m benchmarks.run [--only fig5]``.
``--smoke`` runs every suite on tiny grids (CI's benchmark job: proves
the drivers execute end to end and emits ``BENCH_sweep.json`` without
burning minutes of runner time).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import fig4_platforms, fig5_llc, fig6_interference
from benchmarks import kernel_bench, roofline, socsim_bench

SUITES = {
    "fig4": fig4_platforms.run,
    "fig5": fig5_llc.run,
    "fig6": fig6_interference.run,
    "kernels": kernel_bench.run,
    "roofline": roofline.run,
    "socsim": socsim_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grids, assert JSON emission (CI)")
    args = ap.parse_args()
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    print("name,value,note")
    failed = 0
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for row in fn(smoke=args.smoke):
                print(",".join(str(x) for x in row))
        except Exception as e:  # keep the suite going, flag at exit
            failed += 1
            print(f"{name}/ERROR,{type(e).__name__},{e}", file=sys.stderr)
        print(f"_meta/{name}_seconds,{time.time()-t0:.1f},")
    if failed:
        raise SystemExit(f"{failed} suites failed")
    if args.smoke and not args.only:
        path = os.environ.get("BENCH_SWEEP_JSON", "BENCH_sweep.json")
        with open(path) as f:           # smoke contract: JSON must exist
            json.load(f)
        print(f"_meta/bench_json,{path},valid")


if __name__ == "__main__":
    main()
