"""Fig. 5 — NVDLA speedup from sharing the LLC (size x block-size grid).

Driven by ``repro.core.sweep.sweep_llc``: the closed-form timing grid
(anchored against the paper's bars) plus exact simulated hit rates for
every geometry from the vmapped segment-lane engine over a real DBB
window.  On top of that, the sim-driven path (the ROADMAP item): for
the paper-anchored geometries, ``accel_time_s(mode="simulated")`` feeds
every layer's hit rates from the exact simulator on its own DBB trace,
and ``recalibrate_stream_conflict`` re-fits the closed form's conflict
constant against a full-frame simulated grid.
"""
from __future__ import annotations

from repro.core.sweep import sweep_llc

PAPER_ANCHORS = {
    (0.5, 64): 1.17, (64, 64): 1.28,
    (1024, 32): 1.01, (1024, 64): 1.25, (1024, 128): 1.51,
    (4096, 128): 1.56,
}


def run(smoke: bool = False) -> list[tuple]:
    if smoke:
        sw = sweep_llc(sizes_kib=(0.5, 1024), blocks=(32, 64),
                       window_bursts=512)
    else:
        sw = sweep_llc(sizes_kib=(0.5, 2, 8, 64, 512, 1024, 4096),
                       blocks=(32, 64, 128))
    rows = [("fig5/no_llc_ms", round(sw.no_llc_s * 1e3, 2), "baseline")]
    for (size, block), sp in sorted(sw.speedups.items()):
        paper = PAPER_ANCHORS.get((size, block))
        note = f"paper: {paper}" if paper else ""
        rows.append((f"fig5/llc_{size}KiB_{block}B", round(sp, 3), note))
    for (size, block), hr in sorted(sw.sim_hit_rates.items()):
        rows.append((f"fig5/simhit_{size}KiB_{block}B", round(hr, 3),
                     f"exact sim, {sw.window_bursts}-burst window"))
    if smoke:
        return rows
    rows.extend(_sim_driven_rows())
    return rows


def _sim_driven_rows() -> list[tuple]:
    """Speedups with op_cycles driven by the exact simulator, plus the
    closed-form re-calibration against the same simulated grid — one
    full-frame lane-engine replay feeds both (the per-op fold gives the
    timing model's hit rates, the per-lane sums give the overall rates
    the re-calibration fits)."""
    import dataclasses

    from repro.core import traces
    from repro.core.accelerator import (
        _fold_op_stream_rates,
        accel_time_s,
        recalibrate_stream_conflict,
    )
    from repro.core.runtime import compile_network
    from repro.core.soc import SoCConfig, llc_config_for
    from repro.core.sweep import segment_lane_hit_counts

    soc = SoCConfig()
    stream = compile_network(conv_buf_bytes=soc.accel.conv_buf_bytes)
    sizes = sorted({s for s, _ in PAPER_ANCHORS})
    blocks = sorted({b for _, b in PAPER_ANCHORS})
    points = [(s, b) for s in sizes for b in blocks]
    cfgs = [llc_config_for(s, b) for s, b in points]
    per_op = traces.network_op_segments(stream)
    flat = [seg for segs in per_op for seg in segs]
    counts = segment_lane_hit_counts(flat, cfgs)   # the one grid replay
    total = traces.total_bursts(flat)
    base = accel_time_s(
        stream, acc=soc.accel,
        mem=dataclasses.replace(soc.mem, llc=None))["seconds"]
    rows = []
    for size, block in sorted(points):
        idx = points.index((size, block))
        mem = dataclasses.replace(soc.mem, llc=cfgs[idx])
        hr = _fold_op_stream_rates(per_op, counts[idx])
        t = accel_time_s(stream, acc=soc.accel, mem=mem,
                         hit_rates=hr)["seconds"]
        paper = PAPER_ANCHORS.get((size, block))
        note = ("sim-driven op_cycles, full frame" +
                (f", paper: {paper}" if paper else ""))
        rows.append((f"fig5/simdrv_{size}KiB_{block}B",
                     round(base / t, 3), note))
    sim_rates = {points[i]: float(counts[i].sum()) / total
                 for i in range(len(points))}
    cal = recalibrate_stream_conflict(sim_rates)
    rows.append(("fig5/recal_conflict_blocks",
                 round(cal["stream_conflict_blocks"], 3),
                 f"shipped: {cal['shipped']}"))
    rows.append(("fig5/recal_rms_shipped", round(cal["rms_shipped"], 4),
                 f"closed form vs simulated grid, {cal['points']} points"))
    rows.append(("fig5/recal_rms_fit", round(cal["rms_fit"], 4),
                 "best single-constant fit"))
    return rows
