"""Fig. 5 — NVDLA speedup from sharing the LLC (size x block-size grid)."""
from __future__ import annotations

from repro.core import llc_sweep

PAPER_ANCHORS = {
    (0.5, 64): 1.17, (64, 64): 1.28,
    (1024, 32): 1.01, (1024, 64): 1.25, (1024, 128): 1.51,
    (4096, 128): 1.56,
}


def run() -> list[tuple]:
    sw = llc_sweep(sizes_kib=(0.5, 2, 8, 64, 512, 1024, 4096),
                   blocks=(32, 64, 128))
    rows = [("fig5/no_llc_ms", round(sw["no_llc_s"] * 1e3, 2), "baseline")]
    for (size, block), sp in sorted(sw["grid"].items()):
        paper = PAPER_ANCHORS.get((size, block))
        note = f"paper: {paper}" if paper else ""
        rows.append((f"fig5/llc_{size}KiB_{block}B", round(sp, 3), note))
    return rows
