"""Fig. 5 — NVDLA speedup from sharing the LLC (size x block-size grid).

Driven by ``repro.core.sweep.sweep_llc``: the closed-form timing grid
(anchored against the paper's bars) plus exact simulated hit rates for
every geometry from one vmapped device program over a real interleaved
DBB window — the simulation layer the closed form is validated against,
now cheap enough to run at every sweep point.
"""
from __future__ import annotations

from repro.core.sweep import sweep_llc

PAPER_ANCHORS = {
    (0.5, 64): 1.17, (64, 64): 1.28,
    (1024, 32): 1.01, (1024, 64): 1.25, (1024, 128): 1.51,
    (4096, 128): 1.56,
}


def run() -> list[tuple]:
    sw = sweep_llc(sizes_kib=(0.5, 2, 8, 64, 512, 1024, 4096),
                   blocks=(32, 64, 128))
    rows = [("fig5/no_llc_ms", round(sw["no_llc_s"] * 1e3, 2), "baseline")]
    for (size, block), sp in sorted(sw["grid"].items()):
        paper = PAPER_ANCHORS.get((size, block))
        note = f"paper: {paper}" if paper else ""
        rows.append((f"fig5/llc_{size}KiB_{block}B", round(sp, 3), note))
    for (size, block), hr in sorted(sw["sim_hit_rates"].items()):
        rows.append((f"fig5/simhit_{size}KiB_{block}B", round(hr, 3),
                     f"exact sim, {sw['window_bursts']}-burst window"))
    return rows
