"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(+ analytic TPU-roofline projections for the Pallas kernels).

Pallas interpret mode is a correctness harness, not a performance one —
wall-clock timing happens on the jnp reference path (what XLA:CPU makes
of the same math), while the projected TPU numbers come from the kernels'
FLOP/byte counts against v5e peaks (197 int8-TOPS/2, 819 GB/s).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.convcore.ref import matmul_int8_ref
from repro.kernels.swa.ref import swa_attention_ref

PEAK_INT8 = 394e12
PEAK_BF16 = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))     # one warmup call (works on pytrees)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(smoke: bool = False) -> list[tuple]:
    rows = []
    # convcore GEMM: a darknet-53 mid layer as GEMM (52*52 x 1152 x 256)
    m, k, n = (338, 576, 128) if smoke else (2704, 1152, 256)
    a = jax.random.randint(jax.random.PRNGKey(0), (m, k), -127, 128, jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (k, n), -127, 128, jnp.int8)
    scale = jnp.ones((n,), jnp.float32)
    bias = jnp.zeros((n,), jnp.float32)
    f = jax.jit(lambda a, b: matmul_int8_ref(a, b, scale, bias))
    dt = _time(f, a, b)
    flops = 2 * m * k * n
    rows.append(("kernel/convcore_gemm_cpu_us", round(dt * 1e6, 1),
                 f"{flops/dt/1e9:.1f} GOP/s on CPU ref"))
    rows.append(("kernel/convcore_gemm_tpu_projected_us",
                 round(flops / PEAK_INT8 * 1e6, 2), "v5e int8 roofline"))

    # swa attention: one mixtral-ish head block
    bh, s, d, w = (2, 256, 64, 128) if smoke else (8, 1024, 128, 256)
    q = jax.random.normal(jax.random.PRNGKey(2), (bh, s, d), jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(3), (bh, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (bh, s, d), jnp.float32)
    g = jax.jit(lambda q, k, v: swa_attention_ref(q, k, v, window=w))
    dt = _time(g, q, kk, v)
    # banded flops: 2 matmuls * 2 flops * bh * s * w * d
    fl = 4 * bh * s * w * d
    rows.append(("kernel/swa_cpu_us", round(dt * 1e6, 1),
                 f"banded {fl/dt/1e9:.1f} GFLOP/s on CPU ref"))
    rows.append(("kernel/swa_tpu_projected_us",
                 round(fl / PEAK_BF16 * 1e6, 2), "v5e bf16 roofline"))
    return rows
