"""Fig. 4 — YOLOv3 fps across platforms (NVDLA / Rocket / Xeon / Titan Xp)."""
from __future__ import annotations

from repro.core import platform_table


def run(smoke: bool = False) -> list[tuple]:
    t = platform_table()
    rows = [("fig4/" + k.replace(" ", "_"), round(v, 4), "fps")
            for k, v in t.items() if k != "_meta"]
    m = t["_meta"]
    rows += [
        ("fig4/nvdla_accel_ms", round(m["nvdla_accel_ms"], 2), "paper: 67"),
        ("fig4/nvdla_cpu_ms", round(m["nvdla_cpu_ms"], 2), "paper: 66"),
        ("fig4/speedup_vs_rocket", round(m["speedup_vs_rocket"], 1),
         "paper: 407"),
        ("fig4/gops_per_frame", round(m["gops"], 2), "paper: 66"),
    ]
    return rows
