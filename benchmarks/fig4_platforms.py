"""Fig. 4 — YOLOv3 fps across platforms, extended to a multi-backend
accelerator study.

The paper's figure compares one accelerator (NVDLA) against CPUs and a
GPU.  With the systolic NPU backend (``repro.core.npu``) the platform
table becomes a real head-to-head: per conv layer, NVDLA's
fixed-function pipeline vs the weight-stationary GEMM array vs each
engine's roofline floor (peak-MAC compute bound vs streaming-DRAM
memory bound), both backends priced by the *same* exact segment
LLC simulation on their own real DBB traces (``mode="simulated"``) —
plus whole-workload model-mode times for every NPU zoo workload.

Emits ``BENCH_npu.json`` (``BENCH_NPU_JSON`` overrides) for CI to
archive, and raises on sanity violations (a modeled time beating its
own compute roofline, a hit rate outside [0, 1]) so ``benchmarks.run``
reports a hard FAIL instead of archiving nonsense.
"""
from __future__ import annotations

import json
import os

from repro.core import npu as npu_mod, platform_table
from repro.core.accelerator import (AccelConfig, MemSystemConfig,
                                    op_cycles, op_stream_hit_rates)
from repro.core.runtime import compile_network


def _roofline_cycles(macs: int, min_bytes: int, peak_macs: float,
                     mem: MemSystemConfig, freq_hz: float) -> float:
    """The classic two-term floor: peak-MAC compute bound vs streaming
    every operand byte from DRAM exactly once at peak bandwidth."""
    bw_bytes_per_cycle = (mem.dram.peak_bw / freq_hz) * mem.dram_bw_share
    return max(macs / peak_macs, min_bytes / bw_bytes_per_cycle)


def _layer_study(max_ops: int, acc: AccelConfig, mem: MemSystemConfig,
                 cfg: npu_mod.NPUConfig) -> list[dict]:
    """Per-conv-layer NVDLA vs NPU vs roofline, simulated hit rates on
    both backends' real trace prefixes."""
    stream = compile_network()
    gemms = npu_mod.yolov3_gemms(max_layers=max_ops)
    # the stream interleaves shortcut (SDP) ops between convs — truncate
    # it at the op that completes the max_ops-th conv so both backends
    # simulate the same network prefix
    conv_ops, stream_ops = [], 0
    for op in stream.accel_ops:
        stream_ops += 1
        if op.macs:
            conv_ops.append(op)
            if len(conv_ops) == max_ops:
                break
    nv_rates = op_stream_hit_rates(stream, mem, max_ops=stream_ops)
    nv_by_index = {
        op.layer.index: op_cycles(op, acc, mem, hit_rates=hr)
        for op, hr in zip(stream.accel_ops[:stream_ops], nv_rates)
        if op.macs}
    npu_rates = npu_mod.op_stream_hit_rates(gemms, cfg, mem)
    layers = []
    for op, g, hr in zip(conv_ops, gemms, npu_rates):
        nv = nv_by_index[op.layer.index]
        np_ = npu_mod.op_cycles(g, cfg, mem, hit_rates=hr)
        nv_min_bytes = (op.layer.weight_bytes + op.layer.ifmap_bytes
                        + op.layer.ofmap_bytes)
        np_min_bytes = (g.m * g.k + g.k * g.n + g.m * g.n) * cfg.elem_bytes
        roof_nv = _roofline_cycles(op.macs, nv_min_bytes, acc.macs, mem,
                                   acc.freq_hz)
        roof_np = _roofline_cycles(g.macs, np_min_bytes,
                                   cfg.peak_macs_per_cycle, mem,
                                   cfg.freq_hz)
        for label, res, macs, peak in (
                ("nvdla", nv, op.macs, acc.macs),
                ("npu", np_, g.macs, cfg.peak_macs_per_cycle)):
            if not res["total"] > 0 or res["total"] != res["total"]:
                raise AssertionError(
                    f"layer {op.layer.index}: {label} total cycles "
                    f"{res['total']!r} is not a positive number")
            # the compute term of the roofline is a hard floor; the
            # memory term is not (LLC hits absorb traffic the
            # streaming-DRAM bound assumes must move)
            if res["compute"] < (macs / peak) * 0.999:
                raise AssertionError(
                    f"layer {op.layer.index}: {label} compute cycles "
                    f"{res['compute']:.0f} beat the peak-MAC floor "
                    f"{macs / peak:.0f}")
            for h in res["hit_rates"]:
                if not 0.0 <= h <= 1.0:
                    raise AssertionError(
                        f"layer {op.layer.index}: {label} hit rate {h} "
                        "outside [0, 1]")
        layers.append({
            "layer": op.layer.index, "m": g.m, "k": g.k, "n": g.n,
            "macs": g.macs,
            "nvdla_ms": nv["total"] / acc.freq_hz * 1e3,
            "npu_ms": np_["total"] / cfg.freq_hz * 1e3,
            "npu_utilization": np_["utilization"],
            "roofline_nvdla_ms": roof_nv / acc.freq_hz * 1e3,
            "roofline_npu_ms": roof_np / cfg.freq_hz * 1e3,
            "nvdla_hit_rates": [round(h, 6) for h in nv["hit_rates"]],
            "npu_hit_rates": [round(h, 6) for h in np_["hit_rates"]],
        })
    return layers


def run(smoke: bool = False) -> list[tuple]:
    t = platform_table()
    rows = [("fig4/" + k.replace(" ", "_"), round(v, 4), "fps")
            for k, v in t.items() if k != "_meta"]
    m = t["_meta"]
    rows += [
        ("fig4/nvdla_accel_ms", round(m["nvdla_accel_ms"], 2), "paper: 67"),
        ("fig4/nvdla_cpu_ms", round(m["nvdla_cpu_ms"], 2), "paper: 66"),
        ("fig4/speedup_vs_rocket", round(m["speedup_vs_rocket"], 1),
         "paper: 407"),
        ("fig4/gops_per_frame", round(m["gops"], 2), "paper: 66"),
    ]

    # -- NVDLA vs NPU vs roofline -----------------------------------------
    acc, mem, cfg = AccelConfig(), MemSystemConfig(), npu_mod.NPUConfig()
    max_ops = 4 if smoke else 12
    layers = _layer_study(max_ops, acc, mem, cfg)
    frame = {}
    for name in sorted(npu_mod.WORKLOADS):
        res = npu_mod.npu_time_s(npu_mod.workload(name), npu=cfg, mem=mem)
        frame[name] = {
            "ms": res["seconds"] * 1e3,
            "ops": len(res["per_layer"]),
            "compute_bound_layers": res["compute_bound_layers"],
        }
    out = {
        "smoke": bool(smoke), "max_ops": max_ops,
        "npu_config": {"rows": cfg.rows, "cols": cfg.cols,
                       "ifm_buf_bytes": cfg.ifm_buf_bytes,
                       "wgt_buf_bytes": cfg.wgt_buf_bytes,
                       "acc_buf_bytes": cfg.acc_buf_bytes},
        "layers": layers,
        "npu_model_ms": frame,
        "nvdla_frame_ms": m["nvdla_accel_ms"],
    }
    path = os.environ.get("BENCH_NPU_JSON", "BENCH_npu.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")

    nv_ms = sum(la["nvdla_ms"] for la in layers)
    np_ms = sum(la["npu_ms"] for la in layers)
    rows += [
        ("fig4/backends_layers_compared", len(layers), "conv prefix"),
        ("fig4/backends_nvdla_prefix_ms", round(nv_ms, 3), "simulated"),
        ("fig4/backends_npu_prefix_ms", round(np_ms, 3), "simulated"),
        ("fig4/backends_npu_vs_nvdla", round(nv_ms / np_ms, 3),
         ">1 means NPU faster on prefix"),
        ("fig4/npu_yolov3_frame_ms",
         round(frame["yolov3"]["ms"], 2), "model mode, 75 GEMMs"),
        ("fig4/npu_util_mean",
         round(sum(la["npu_utilization"] for la in layers) / len(layers),
               4), "PE-array utilization"),
    ]
    for name in ("transformer_decode", "mamba2_decode", "whisper_encoder"):
        rows.append((f"fig4/npu_{name}_ms", round(frame[name]["ms"], 3),
                     f"{frame[name]['ops']} GEMMs, model mode"))
    return rows
