"""Microbatched train step with optional int8 gradient compression.

``make_train_step`` builds the jittable step function:

* microbatching — the global batch is split into ``microbatches`` chunks
  and gradients are accumulated with a ``lax.scan`` (bounds activation
  memory; the accumulator is fp32);
* the model forward remats at layer-group boundaries (``cfg.remat``);
* optional gradient compression (``repro.train.compress``) applies an
  int8 + error-feedback codec across the ``pod`` mesh axis before the
  optimizer — the cross-pod wire format becomes int8 (4x fewer collective
  bytes on the slowest links), with the quantization error carried to the
  next step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.train import compress as compress_mod
from repro.train.optim import AdamWConfig, adamw_init, adamw_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: dict
    step: jax.Array
    ef: Any | None = None  # error-feedback buffers (grad compression)


def init_train_state(params, *, compress: bool = False) -> TrainState:
    ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if compress else None
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, compress_axis: str | None = None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        return grads, metrics

    def train_step(state: TrainState, batch: dict):
        if microbatches > 1:
            mb = _split_microbatches(batch, microbatches)

            def body(acc, one):
                g, m = grads_of(state.params, one)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / microbatches,
                    acc, g)
                return acc, m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, ms = jax.lax.scan(body, zero, mb,
                                     unroll=cfg.unroll_scans)
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)
        else:
            grads, metrics = grads_of(state.params, batch)

        ef = state.ef
        if compress_axis is not None:
            grads, ef = compress_mod.compressed_reduce(
                grads, state.ef, axis=compress_axis)

        params, opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        metrics = {**metrics, **opt_metrics}
        return TrainState(params=params, opt=opt, step=state.step + 1, ef=ef), metrics

    return train_step
