"""Fault-tolerant training loop: checkpoint/restart, watchdog, stragglers.

Designed for 1000+-node operation:

* **checkpoint/restart** — periodic atomic checkpoints via
  ``CheckpointManager``; on (re)start the loop resumes from the latest
  committed step, and the deterministic data stream replays the exact
  batch sequence, so a restarted run is bit-compatible with an unfailed
  one (tested by killing the loop mid-run).
* **failure injection** — ``failure_hook(step)`` raises to simulate a node
  loss; the driver catches, restores, and continues (bounded retries).
* **straggler watchdog** — per-step wall time is tracked against an EMA;
  steps slower than ``straggler_factor``× the EMA are recorded (on real
  fleets this feeds the scheduler that evicts/replaces slow hosts; here it
  is surfaced in the step log and summary).
* **elastic scaling** — checkpoints store global logical arrays, so a
  resume may use a different mesh; pass a new ``shardings`` tree at
  restore time (see tests/test_checkpoint.py::test_elastic_reshard).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.synthetic import SyntheticStream
from repro.models import init_params
from repro.train.optim import AdamWConfig
from repro.train.step import TrainState, init_train_state, make_train_step
from repro.types import param_values


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_save: bool = True
    max_restarts: int = 3
    straggler_factor: float = 2.0
    log_every: int = 10
    microbatches: int = 1


@dataclasses.dataclass
class LoopResult:
    state: TrainState
    losses: list
    straggler_steps: list
    restarts: int


def _run_segment(state, stream, step_fn, loop_cfg, manager, losses,
                 straggler_steps, failure_hook, log) -> TrainState:
    ema = None
    start = int(state.step)
    for step in range(start, loop_cfg.total_steps):
        if failure_hook is not None:
            failure_hook(step)  # may raise to simulate a node failure
        batch = stream.batch_at(step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])  # blocks; acts as the step barrier
        dt = time.perf_counter() - t0
        losses.append(loss)
        if ema is None:
            ema = dt
        elif dt > loop_cfg.straggler_factor * ema:
            straggler_steps.append((step, dt, ema))
            log(f"[watchdog] step {step} took {dt*1e3:.1f} ms "
                f"(> {loop_cfg.straggler_factor:.1f}x EMA {ema*1e3:.1f} ms)")
        ema = 0.9 * ema + 0.1 * dt if ema else dt
        if step % loop_cfg.log_every == 0:
            log(f"step {step:5d}  loss {loss:.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  {dt*1e3:.0f} ms")
        if (step + 1) % loop_cfg.checkpoint_every == 0:
            manager.save(state, step + 1)
    return state


def train(cfg: ModelConfig, opt_cfg: AdamWConfig, loop_cfg: LoopConfig, *,
          global_batch: int, seq_len: int, seed: int = 0,
          failure_hook: Callable[[int], None] | None = None,
          log: Callable[[str], None] = print) -> LoopResult:
    """Run (or resume) training; survives `failure_hook` exceptions."""
    stream = SyntheticStream(cfg, global_batch, seq_len, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=loop_cfg.microbatches))
    manager = CheckpointManager(loop_cfg.checkpoint_dir, keep=loop_cfg.keep,
                                async_save=loop_cfg.async_save)

    def fresh_state() -> TrainState:
        params = param_values(init_params(jax.random.PRNGKey(seed), cfg))
        return init_train_state(params)

    state = fresh_state()
    try:
        state = manager.restore_latest(state)
        log(f"resumed from step {int(state.step)}")
    except FileNotFoundError:
        pass

    losses: list = []
    straggler_steps: list = []
    restarts = 0
    while True:
        try:
            state = _run_segment(state, stream, step_fn, loop_cfg, manager,
                                 losses, straggler_steps, failure_hook, log)
            break
        except RuntimeError as e:  # simulated node failure
            restarts += 1
            if restarts > loop_cfg.max_restarts:
                raise
            log(f"[failure] {e}; restart {restarts}/{loop_cfg.max_restarts}")
            state = fresh_state()
            try:
                state = manager.restore_latest(state)
                log(f"restored step {int(state.step)}")
            except FileNotFoundError:
                log("no checkpoint yet; restarting from scratch")
    manager.wait()
    return LoopResult(state=state, losses=losses,
                      straggler_steps=straggler_steps, restarts=restarts)
