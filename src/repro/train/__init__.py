from repro.train.optim import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    lr_schedule,
)
from repro.train.step import (  # noqa: F401
    TrainState,
    init_train_state,
    make_train_step,
)
