from repro.train.optim import AdamWConfig, adamw_init, adamw_update, lr_schedule  # noqa: F401
from repro.train.step import TrainState, make_train_step, init_train_state  # noqa: F401
