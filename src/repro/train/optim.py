"""AdamW + warmup/cosine schedule + global-norm clipping, dependency-free.

Moments are fp32 regardless of parameter dtype (mixed-precision convention:
bf16/fp32 params, fp32 optimizer state).  All update math is fp32 with a
final cast back to the parameter dtype.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * t))
    frac = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    gnorm = jnp.sqrt(jnp.sum(jnp.stack(leaves)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(cfg: AdamWConfig, grads, opt_state: dict, params):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = opt_state["count"] + 1
    lr = lr_schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(gf)
        mhat = m_new / b1c
        vhat = v_new / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard: skip norms/biases)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([n[0] for n in new])
    new_m = treedef.unflatten([n[1] for n in new])
    new_v = treedef.unflatten([n[2] for n in new])
    return new_params, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gnorm, "lr": lr}
