"""Int8 gradient compression with error feedback (cross-pod all-reduce).

The paper's interference analysis concludes that traffic on shared links
must be managed explicitly; on a multi-pod mesh the scarcest link is the
inter-pod one.  This codec reduces the *cross-pod* gradient reduction to an
int8 wire format:

    q     = round(g / scale),  scale = max|g| / 127   (per tensor)
    g_hat = psum(q) * scale'                            (int8 on the wire)
    e'    = g + e - dequant(q)                          (error feedback)

Error feedback makes the compression *unbiased over time*: the quantization
residual is added back into the next step's gradient, which is the standard
convergence-preserving trick (1-bit Adam / EF-SGD lineage).

``compressed_reduce`` works in two contexts:
* inside ``shard_map`` with a bound mesh axis — does a real ``psum`` of the
  int8 payload (the HLO all-reduce operand is int8: 4x fewer bytes on the
  pod links, visible in the dry-run collective parse);
* outside (single-device tests) — degrades to quantize/dequantize with
  error feedback, preserving numerics for convergence tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jax.Array):
    """g -> (q int8, scale fp32)."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _axis_bound(axis: str) -> bool:
    try:
        jax.lax.axis_index(axis)
        return True
    except NameError:
        return False


def compressed_reduce(grads, ef, *, axis: str):
    """Error-feedback int8 reduction of a gradient pytree.

    Returns (reduced_grads fp32, new_error_feedback).  When `axis` is not a
    bound shard_map axis this is a pure quantize/dequantize round-trip with
    error feedback (numerics identical to the 1-pod case).
    """
    bound = _axis_bound(axis)

    def one(g, e):
        gf = g.astype(jnp.float32) + (e if e is not None else 0.0)
        q, scale = quantize(gf)
        if bound:
            n = jax.lax.psum(1, axis)
            # int8 payload on the wire; accumulate in int32 locally
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            ssum = jax.lax.psum(scale, axis)
            g_hat = qsum.astype(jnp.float32) * (ssum / n) / n
        else:
            g_hat = dequantize(q, scale)
        e_new = gf - dequantize(q, scale)
        return g_hat, e_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef) if ef is not None else [None] * len(flat_g)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
