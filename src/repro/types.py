"""Core parameter / pytree plumbing shared by every subsystem.

Parameters carry *logical axis names* alongside their values so the sharding
resolver (``repro.sharding``) can map them onto whatever mesh is in scope
without the model code knowing mesh geometry.  This is the same split used by
production JAX frameworks (MaxText / t5x "logical axes"), kept dependency-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    """A parameter value annotated with logical axis names.

    ``axes`` has one entry per array dimension; ``None`` means "never shard
    this dimension".  Registered as a pytree node so Param trees pass through
    ``vmap`` (layer stacking), ``eval_shape`` (abstract init for the dry-run)
    and ``jit`` unchanged.  Rank/axes agreement is *not* enforced in the
    constructor — ``vmap`` legitimately rebuilds Params with an extra batch
    dimension — use :func:`validate_params` in tests instead.
    """

    value: Any
    axes: tuple[str | None, ...]


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: Param(children[0], axes),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def validate_params(tree) -> None:
    """Assert every Param's axes tuple matches its value rank."""
    def _check(p: Param):
        if hasattr(p.value, "ndim") and len(p.axes) != p.value.ndim:
            raise ValueError(
                f"axes {p.axes} rank mismatch for value of shape {p.value.shape}"
            )
        return p

    jax.tree.map(_check, tree, is_leaf=is_param)


@dataclasses.dataclass(frozen=True)
class AxesSpec:
    """Opaque (non-pytree) box for a logical-axes tuple, so an axes tree can
    be zipped against a value tree with ``jax.tree.map``."""

    axes: tuple[str | None, ...]


def param_values(tree):
    """Strip Param wrappers -> plain value pytree."""
    return jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)


def param_axes(tree):
    """Strip Param wrappers -> AxesSpec pytree (same treedef as values)."""
    return jax.tree.map(lambda p: AxesSpec(p.axes), tree, is_leaf=is_param)


def map_params(fn: Callable[[Param], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=is_param)


def tree_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "size")
    )


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def cast_floating(tree, dtype):
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
