"""Runtime environment control: x64 precision and address dtypes.

JAX disables 64-bit types by default; an ``jnp.asarray(x, jnp.int64)``
then *silently* truncates to int32.  For DBB byte addresses that is a
correctness hazard the moment an address crosses 2^31 (an 8 GiB DRAM
map does).  Two tools:

* ``jax_enable_x64`` — flip the global precision switch (call it at
  program start, before any array is built; benchmarks and scripts that
  replay full-frame traces should call it);
* ``as_address_array`` / ``address_dtype`` — build address arrays that
  are int64 under x64 and otherwise int32 *with an explicit overflow
  check*, so truncation can never be silent.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def jax_enable_x64(use_x64: bool = True) -> None:
    """Changes the default precision of arrays in JAX.

    When `use_x64` is True, JAX arrays use 64 bits, else 32 bits.  A
    False argument defers to the ``JAX_ENABLE_X64`` environment
    variable (so scripts can force precision without code changes).
    Call before building any array — flipping mid-program leaves
    already-created arrays at their old width.
    """
    if not use_x64:
        use_x64 = bool(int(os.getenv("JAX_ENABLE_X64", "0")))
    jax.config.update("jax_enable_x64", bool(use_x64))


def x64_enabled() -> bool:
    return bool(jax.config.jax_enable_x64)


def address_dtype():
    """Widest integer dtype currently available for byte addresses."""
    return jnp.int64 if x64_enabled() else jnp.int32


def as_address_array(x, *, what: str = "address") -> jax.Array:
    """Build an address array without silent truncation.

    Under x64 this is a plain int64 array.  Without x64 the values are
    range-checked against int32 before the (lossless) narrowing; out-of
    -range addresses raise instead of wrapping.
    """
    arr = np.asarray(x, np.int64)
    if x64_enabled():
        return jnp.asarray(arr, jnp.int64)
    info = np.iinfo(np.int32)
    if arr.size and (int(arr.max()) > info.max or int(arr.min()) < info.min):
        raise OverflowError(
            f"{what} values exceed int32 range and jax_enable_x64 is off; "
            "call repro.utils.env.jax_enable_x64(True) at program start")
    return jnp.asarray(arr, jnp.int32)
