"""Cross-cutting utilities (runtime environment, precision control)."""
