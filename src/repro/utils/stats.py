"""Deterministic order statistics shared by the serving engine and the
QoS benchmarks.

The repo's latency summaries are *nearest-rank* percentiles — no
interpolation, so every reported number is an actual observed sample
and JSON round-trips bit-stably.  The rank definition is the standard
one: for a sorted sample of size n, the q-th percentile is the value at
1-indexed rank ``ceil(n * q / 100)`` (clamped to [1, n]).

The serving engine's original inline helper truncated ``q * n`` to an
integer before the ceiling division, which is exact for integer q but
off by one for fractional q whenever ``int(q * n)`` lands on a multiple
of 100 (e.g. q=33.35, n=3: the true rank is ceil(1.0005) = 2, the
truncating formula gave 1).  ``nearest_rank`` computes the ceiling on
the untruncated product; tests/test_stats.py pins the behavior with a
hypothesis property suite (monotonicity in q, membership, exact values
on known small lists, and the degenerate windows: empty, single-sample,
p=99 with n < 100).
"""
from __future__ import annotations

import math
from typing import Sequence


def nearest_rank(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an ascending-sorted sample.

    ``q`` is in percent (p50 -> q=50).  Degenerate windows: an empty
    sample returns 0.0 (the engine's "no finished requests yet"
    convention); a single sample is every percentile of itself; and for
    n < 100 the p99 is the maximum (rank ceil(0.99 * n) == n exactly
    when n < 100 — the tail statistic saturates at the worst observed
    sample, it never rounds *down* past it).
    """
    n = len(sorted_vals)
    if n == 0:
        return 0.0
    k = min(n, max(1, math.ceil(n * q / 100)))
    return sorted_vals[k - 1]


def latency_summary(latencies: Sequence[float]) -> dict:
    """p50/p99/WCET/mean of an (unsorted) latency sample, as a flat
    JSON-stable dict — the per-curve record shape of the QoS suite
    (``benchmarks/fig6_tail.py``) and anything else reporting tail
    behavior."""
    vals = sorted(float(v) for v in latencies)
    n = len(vals)
    return {
        "n": n,
        "mean": (sum(vals) / n) if n else 0.0,
        "p50": nearest_rank(vals, 50),
        "p99": nearest_rank(vals, 99),
        "wcet": vals[-1] if n else 0.0,
    }
