"""Deterministic synthetic token pipeline.

Production data loaders are host-sharded: each host materialises only its
slice of the global batch.  The stream here is (a) *deterministic in
(seed, step)* — restart/resume yields bit-identical batches, which the
fault-tolerance tests rely on — and (b) *host-shardable* — a host only
generates ``[host_offset : host_offset + per_host]`` rows, and any
(num_hosts, host_id) decomposition yields the same global batch.

Tokens follow a Zipfian-ish distribution (realistic softmax/label traffic,
exercises the padded-vocab masking) with a learnable bigram structure so
short training runs have signal: token[t+1] depends on token[t] through a
fixed random permutation, so a model can reduce loss well below uniform.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def _zipf_logits(vocab: int) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    return np.log(1.0 / ranks)


def _tokens_for_rows(cfg: ModelConfig, rows: np.ndarray, seq_len: int,
                     seed: int, step: int) -> np.ndarray:
    """Generate (len(rows), seq_len+1) tokens deterministically per row."""
    v = cfg.vocab_size
    zipf = _zipf_logits(v)
    zipf_p = np.exp(zipf - zipf.max())
    zipf_p /= zipf_p.sum()
    perm = np.random.default_rng(seed ^ 0x5EED).permutation(v)
    out = np.empty((len(rows), seq_len + 1), dtype=np.int32)
    for i, r in enumerate(rows):
        rng = np.random.default_rng((seed * 1_000_003 + step) * 1_000_003 + int(r))
        toks = rng.choice(v, size=seq_len + 1, p=zipf_p)
        # bigram structure: with p=0.5 the next token is perm[prev]
        follow = rng.random(seq_len) < 0.5
        for t in range(seq_len):
            if follow[t]:
                toks[t + 1] = perm[toks[t]]
        out[i] = toks
    return out


@dataclasses.dataclass
class SyntheticStream:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def per_host(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> dict:
        rows = np.arange(self.host_id * self.per_host,
                         (self.host_id + 1) * self.per_host)
        toks = _tokens_for_rows(self.cfg, rows, self.seq_len, self.seed, step)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        _add_frontend_stubs(batch, self.cfg, self.per_host, self.seed, step)
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def _add_frontend_stubs(batch: dict, cfg: ModelConfig, b: int, seed: int,
                        step: int) -> None:
    """Audio/vision frontends are stubs: precomputed embeddings."""
    if cfg.is_encoder_decoder:
        key = jax.random.PRNGKey(seed * 7919 + step)
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm" and cfg.num_patches:
        key = jax.random.PRNGKey(seed * 104729 + step + 1)
        batch["patches"] = jax.random.normal(
            key, (b, cfg.num_patches, cfg.d_model), jnp.float32)


def make_batch(cfg: ModelConfig, batch: int, seq_len: int, *, seed: int = 0,
               step: int = 0) -> dict:
    """One-shot batch (tests / examples)."""
    return SyntheticStream(cfg, batch, seq_len, seed=seed).batch_at(step)
