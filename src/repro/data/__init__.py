from repro.data.synthetic import SyntheticStream, make_batch  # noqa: F401
