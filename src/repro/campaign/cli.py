"""Campaign CLI: ``python -m repro.campaign <command>``.

Commands:

* ``run SPEC --out DIR``  — run a campaign; ``--resume`` continues a
  journaled one, ``--inject FAULTS.json`` wires up the deterministic
  fault harness (an injected crash exits with code ``42`` so scripts
  can distinguish a simulated death from a real error, then resume);
* ``example``             — print a tiny ready-to-run spec to stdout;
* ``faults``              — print a fault-plan JSON from point indices;
* ``show DIR``            — summarize a campaign directory's journal
  and manifest (completed/failed/pending counts).

Exit codes: 0 all points completed; 3 campaign finished but quarantined
points remain; 42 an injected fault simulated a process death (resume
with ``--resume``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.campaign.executor import RetryPolicy, run_campaign
from repro.campaign.faults import (
    FaultInjector,
    InjectedCrash,
    plan_from_indices,
)
from repro.campaign.manifest import JOURNAL_NAME, MANIFEST_NAME, Journal
from repro.campaign.spec import CampaignSpec, example_spec

EXIT_FAILED_POINTS = 3
EXIT_INJECTED_CRASH = 42


def _cmd_run(args) -> int:
    spec = CampaignSpec.load(args.spec)
    hooks = None
    if args.inject:
        with open(args.inject) as f:
            plan = plan_from_indices(spec, json.load(f))
        hooks = FaultInjector(plan, args.out)
    policy = RetryPolicy(max_retries=args.retries,
                         timeout_s=args.timeout,
                         backoff_s=args.backoff)
    mesh = None
    if args.mesh is not None:
        import jax

        from repro.launch.mesh import make_sweep_mesh
        devices = (jax.devices() if args.mesh < 0
                   else jax.devices()[:args.mesh])
        mesh = make_sweep_mesh(devices)
        print(f"sweep mesh: {len(mesh.devices.ravel())} device(s)",
              file=sys.stderr)
    try:
        res = run_campaign(spec, args.out, resume=args.resume,
                           overwrite=args.overwrite, policy=policy,
                           hooks=hooks, retry_failed=args.retry_failed,
                           progress=lambda m: print(m, file=sys.stderr),
                           mesh=mesh, batch_points=args.batch_points)
    except InjectedCrash as e:
        print(f"simulated process death: {e}", file=sys.stderr)
        return EXIT_INJECTED_CRASH
    print(json.dumps(res.manifest["counts"]))
    return EXIT_FAILED_POINTS if res.failed else 0


def _cmd_example(args) -> int:
    spec = example_spec(points=args.points,
                        window_bursts=args.window_bursts)
    json.dump(spec.to_dict(), sys.stdout, indent=2, sort_keys=True)
    print()
    return 0


def _cmd_faults(args) -> int:
    entries = []
    for kind in ("crash", "hang", "nan", "corrupt", "torn"):
        for idx in getattr(args, kind) or ():
            entries.append({"point": idx, "kind": kind})
    json.dump(entries, sys.stdout, indent=2)
    print()
    return 0


def _cmd_show(args) -> int:
    journal = Journal(os.path.join(args.dir, JOURNAL_NAME))
    records, dropped = journal.replay()
    kinds = {}
    for rec in records:
        kinds[rec["kind"]] = kinds.get(rec["kind"], 0) + 1
    print(f"journal: {len(records)} records {dict(sorted(kinds.items()))}"
          f", {dropped} corrupt/torn lines")
    manifest_path = os.path.join(args.dir, MANIFEST_NAME)
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            m = json.load(f)
        print(f"manifest: campaign {m['campaign']!r} "
              f"spec {m['spec_hash']} counts {m['counts']}")
        for fp in m["failed_points"]:
            print(f"  failed {fp['point_id']}: {fp.get('error', '')}")
    else:
        print("manifest: not written (campaign incomplete — resume it)")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.campaign",
                                 description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="command", required=True)

    run_p = sub.add_parser("run", help="run or resume a campaign")
    run_p.add_argument("spec", help="campaign spec JSON file")
    run_p.add_argument("--out", required=True, help="campaign directory")
    run_p.add_argument("--resume", action="store_true",
                       help="replay the journal and run only missing points")
    run_p.add_argument("--overwrite", action="store_true",
                       help="discard an existing journal and start over")
    run_p.add_argument("--retry-failed", action="store_true",
                       help="with --resume, also re-run quarantined points")
    run_p.add_argument("--retries", type=int, default=2,
                       help="max retries per point (default 2)")
    run_p.add_argument("--timeout", type=float, default=None,
                       help="per-point wall-clock timeout in seconds")
    run_p.add_argument("--backoff", type=float, default=0.05,
                       help="base retry backoff in seconds")
    run_p.add_argument("--inject", default=None,
                       help="fault-plan JSON (see the 'faults' command)")
    run_p.add_argument("--mesh", nargs="?", const=-1, default=None,
                       type=int, metavar="N",
                       help="shard point batches over a jax device mesh "
                            "(all visible devices, or the first N; on a "
                            "CPU host export XLA_FLAGS=--xla_force_host_"
                            "platform_device_count=K first)")
    run_p.add_argument("--batch-points", type=int, default=32,
                       help="max points per batched lane program "
                            "(1 = strictly sequential; default 32)")
    run_p.set_defaults(func=_cmd_run)

    ex_p = sub.add_parser("example", help="print a tiny example spec")
    ex_p.add_argument("--points", type=int, default=8)
    ex_p.add_argument("--window-bursts", type=int, default=512)
    ex_p.set_defaults(func=_cmd_example)

    f_p = sub.add_parser("faults", help="print a fault plan JSON")
    for kind in ("crash", "hang", "nan", "corrupt", "torn"):
        f_p.add_argument(f"--{kind}", type=int, action="append",
                         metavar="POINT_INDEX",
                         help=f"inject a {kind} fault at this spec-order "
                              "point index (repeatable)")
    f_p.set_defaults(func=_cmd_faults)

    show_p = sub.add_parser("show", help="summarize a campaign directory")
    show_p.add_argument("dir")
    show_p.set_defaults(func=_cmd_show)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.func(args)


if __name__ == "__main__":
    raise SystemExit(main())
