"""Fault-tolerant campaign executor: the run farm for simulated SoCs.

Turns a ``CampaignSpec`` into completed, journaled sweep points the way
FireSim's run-farm manager turns a fleet config into completed FPGA
runs — assuming from the start that workers crash, hang, and return
garbage:

* **sharding** — pending points are grouped into *lane buckets*: points
  sharing (model, mix, DRAM) share one compressed DBB trace, built once
  per bucket, and their geometries are ordered by
  ``repro.core.sweep.lane_buckets`` so compiled lane programs are
  maximally reused;
* **batching** — each bucket runs as ONE vmapped lane program
  (``sweep.interference_lane_metrics_batch``), optionally sharded over
  a ``jax.sharding`` mesh (``repro.launch.mesh.make_sweep_mesh``) so a
  point batch spreads across devices like a FireSim run farm spreads
  simulations across FPGAs.  Batch results are unstacked back into
  per-point journal records — bit-identical to the sequential path —
  and fault handling stays per-point: a point whose attempt fails
  (injected fault, guardrail trip) is retried through the sequential
  path, so quarantine granularity is unchanged;
* **journaling** — every completed point is appended to the campaign's
  checksummed JSONL journal *before* the executor moves on (see
  ``repro.campaign.manifest``); a kill at any instant loses at most the
  in-flight point;
* **resume** — ``resume=True`` replays the journal, drops torn/corrupt
  records by checksum, re-validates every surviving result against the
  closed-form invariants, and re-enqueues exactly the missing points;
* **robustness** — each point runs under an optional wall-clock timeout
  and bounded retry with exponential backoff; results must pass the
  numeric guardrails (finite floats, hits <= accesses, the closed-form
  latency identity, and LRU-inclusion monotonicity of hit counts in
  ways across constant-``sets`` geometry families) or the point is
  retried and, when retries are exhausted, quarantined into the
  manifest's ``failed_points`` section instead of aborting the campaign.

The final ``manifest.json`` is a pure function of (spec, results): a
campaign that survived injected crashes/hangs/NaNs/torn writes ends
bit-identical to an uninterrupted one (tests/test_campaign.py).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import time

from repro.campaign.manifest import (
    JOURNAL_NAME,
    MANIFEST_NAME,
    Journal,
    JournalError,
    atomic_write_json,
    build_manifest,
)
from repro.campaign.spec import CampaignPoint, CampaignSpec, canonical_json
from repro.core.socsim import (
    PipelineInvariantError,
    check_segment_totals,
    check_segment_totals_batch,
)
from repro.core.sweep import LaneMetrics


class GuardrailViolation(RuntimeError):
    """A point's result failed numeric validation — treated like any
    other point failure: retried, then quarantined."""


class PointTimeout(RuntimeError):
    """A point exceeded the per-point wall-clock budget."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-point failure handling: ``max_retries`` *re*-tries after the
    first attempt, exponential backoff between attempts, optional
    wall-clock timeout per attempt (None = unbounded)."""
    max_retries: int = 2
    timeout_s: float | None = None
    backoff_s: float = 0.05
    backoff_factor: float = 2.0

    def backoff(self, attempt: int) -> float:
        return self.backoff_s * self.backoff_factor ** attempt


class PointHooks:
    """Instrumentation seams the fault injector (and tests) plug into.
    The default implementation is a no-op executor pass-through."""

    def before_point(self, point: CampaignPoint, attempt: int) -> None:
        """Called in the main thread before an attempt is dispatched."""

    def in_worker(self, point: CampaignPoint, attempt: int, run):
        """Called inside the (possibly timed) worker; must return the
        result of ``run()`` — or a corrupted stand-in, if injecting."""
        return run()

    def after_append(self, point: CampaignPoint, journal: Journal) -> None:
        """Called after the point's journal record is durably appended."""


@dataclasses.dataclass
class CampaignResult:
    manifest: dict
    manifest_path: str
    executed: int          # points actually run this invocation
    resumed: int           # points restored from the journal
    dropped_records: int   # torn/corrupt journal lines discarded
    failed: dict           # point_id -> failure info

    @property
    def completed(self) -> int:
        return self.manifest["counts"]["completed"]


def run_point(point: CampaignPoint, nvdla_segs: list) -> LaneMetrics:
    """Execute one sweep point: the co-runner-interleaved lane through
    the exact segment LLC engine + closed-form DRAM row model.  Returns
    the typed ``LaneMetrics`` record."""
    from repro.core.sweep import interference_lane_metrics

    return interference_lane_metrics(
        nvdla_segs, llc=point.geometry.llc(), dram=point.dram.dram(),
        mix=point.mix.mix(), chunk_bursts=point.model.chunk_bursts)


def run_batch(points: list[CampaignPoint], nvdla_segs: list,
              mesh=None) -> list[LaneMetrics]:
    """Execute a batch of points sharing one trace as vmapped lane
    programs, optionally sharded over ``mesh``.  Every returned
    ``LaneMetrics`` is bit-identical to ``run_point`` for that point;
    raises (e.g. unsupported stride) mean the caller should fall back
    to the sequential path."""
    from repro.core.sweep import interference_lane_metrics_batch

    chunk_bursts = {p.model.chunk_bursts for p in points}
    if len(chunk_bursts) != 1:
        raise ValueError("batch mixes chunk_bursts values; shard first")
    return interference_lane_metrics_batch(
        nvdla_segs,
        llcs=[p.geometry.llc() for p in points],
        drams=[p.dram.dram() for p in points],
        mixes=[p.mix.mix() for p in points],
        chunk_bursts=chunk_bursts.pop(), mesh=mesh)


def _monotone_family_key(point: CampaignPoint) -> tuple | None:
    """Family under which LRU inclusion makes hit counts monotone in
    ways: identical trace (solo lanes only — co-runner traces depend on
    the LLC size) and identical (sets, block).  None = not comparable."""
    if point.mix.corunners and point.mix.wss != "l1":
        return None
    llc = point.geometry.llc()
    return (canonical_json(point.model.to_dict()),
            canonical_json(point.dram.to_dict()),
            llc.sets, llc.block_bytes)


def validate_result(point: CampaignPoint, result: LaneMetrics,
                    families: dict) -> None:
    """Numeric guardrails for one typed ``LaneMetrics`` result.  Raises
    ``GuardrailViolation`` naming the failed invariant; checks run
    *before* journaling, so a poisoned number never becomes durable.
    Field *types* are still checked — the fault injector (and a
    corrupted journal) can smuggle NaN into a counter field that the
    dataclass type hints merely promise is an int."""
    import math

    for k in LaneMetrics._INT_FIELDS:
        v = getattr(result, k)
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            raise GuardrailViolation(
                f"{point.point_id}: field {k!r} must be a nonnegative "
                f"int, got {v!r}")
    for k in LaneMetrics._FLOAT_FIELDS:
        v = getattr(result, k)
        if not isinstance(v, (int, float)) or not math.isfinite(v):
            raise GuardrailViolation(
                f"{point.point_id}: field {k!r} must be finite, got {v!r}")
    try:
        check_segment_totals(
            accesses=result.accesses, llc_hits=result.llc_hits,
            dram_row_hits=result.dram_row_hits,
            total_cycles=result.total_cycles,
            dram=point.dram.dram(), t_llc_hit=result.t_llc_hit)
    except PipelineInvariantError as e:
        raise GuardrailViolation(f"{point.point_id}: {e}") from e
    if result.nvdla_hits > result.nvdla_accesses:
        raise GuardrailViolation(
            f"{point.point_id}: nvdla_hits {result.nvdla_hits} exceeds "
            f"nvdla_accesses {result.nvdla_accesses}")
    if result.nvdla_hits > result.llc_hits:
        raise GuardrailViolation(
            f"{point.point_id}: nvdla_hits {result.nvdla_hits} exceeds "
            f"whole-lane llc_hits {result.llc_hits} — NVDLA hits are a "
            "subset of the lane's hits")
    key = _monotone_family_key(point)
    if key is None:
        return
    ways = point.geometry.llc().ways
    hits = result.llc_hits
    for other_ways, (other_id, other_hits) in families.get(key, {}).items():
        if ((other_ways <= ways and other_hits > hits)
                or (other_ways >= ways and other_hits < hits)):
            raise GuardrailViolation(
                f"{point.point_id}: llc_hits {hits} at ways={ways} breaks "
                f"LRU inclusion against point {other_id} "
                f"(llc_hits {other_hits} at ways={other_ways}) — "
                "hit counts must be monotone in ways at fixed sets/block")


def _record_family(point: CampaignPoint, result: LaneMetrics,
                   families: dict) -> None:
    key = _monotone_family_key(point)
    if key is not None:
        families.setdefault(key, {})[point.geometry.llc().ways] = (
            point.point_id, result.llc_hits)


def shard_points(points: list[CampaignPoint]) -> list[list[CampaignPoint]]:
    """Deterministic lane-bucket sharding: group points sharing a trace
    (model — mixes and DRAM configs are per-lane operands of the batch
    kernel, so they ride along in one shard), then order each group's
    geometries with ``sweep.lane_buckets`` so similar set counts run
    back to back and compiled lane programs get reused.  Wide shards
    matter on a mesh: every extra shard is another narrow per-device
    scan whose fixed per-step cost is pure overhead."""
    from repro.core.sweep import lane_buckets

    groups: dict[str, list[CampaignPoint]] = {}
    for p in points:
        key = str(p.model.to_dict())
        groups.setdefault(key, []).append(p)
    shards = []
    for group in groups.values():
        cfgs = [p.geometry.llc() for p in group]
        for bucket in lane_buckets(cfgs):
            shards.append([group[i] for i in bucket])
    return shards


def _attempt(point: CampaignPoint, attempt: int, nvdla_segs: list,
             hooks: PointHooks, policy: RetryPolicy,
             compute=None) -> LaneMetrics:
    """One timed attempt at one point.  ``compute`` overrides the
    simulation callable — the batch scheduler passes a closure over the
    point's precomputed batch result, so hooks (fault injection, hangs,
    corruption) still wrap every attempt identically to the sequential
    path."""
    compute = compute or (lambda: run_point(point, nvdla_segs))

    def work():
        return hooks.in_worker(point, attempt, compute)

    if policy.timeout_s is None:
        return work()
    pool = concurrent.futures.ThreadPoolExecutor(
        max_workers=1, thread_name_prefix=f"campaign-{point.point_id[:6]}")
    try:
        future = pool.submit(work)
        try:
            return future.result(timeout=policy.timeout_s)
        except concurrent.futures.TimeoutError:
            future.cancel()
            raise PointTimeout(
                f"{point.point_id}: attempt {attempt} exceeded "
                f"{policy.timeout_s}s") from None
    finally:
        # never block on a hung worker; the thread dies with the process
        pool.shutdown(wait=False)


def _load_journal_state(journal: Journal, spec: CampaignSpec,
                        known_ids: set[str]):
    """Replay + re-validate a journal.  Returns (completed, failed,
    dropped): corrupt lines and records for unknown points are dropped,
    and a completed record whose numbers fail the closed-form
    invariants is *demoted to pending* (dropped) rather than trusted."""
    records, dropped = journal.replay()
    completed: dict[str, dict] = {}
    failed: dict[str, dict] = {}
    points_by_id = {p.point_id: p for p in spec.expand()}
    for rec in records:
        kind = rec["kind"]
        if kind == "spec":
            if rec.get("spec_hash") != spec.spec_hash:
                raise JournalError(
                    f"journal at {journal.path} belongs to campaign "
                    f"spec {rec.get('spec_hash')}, not {spec.spec_hash} — "
                    "refusing to resume a different campaign")
        elif kind == "point":
            pid = rec.get("point_id")
            if pid not in known_ids:
                dropped += 1
                continue
            try:
                metrics = LaneMetrics.from_record(rec["result"])
                validate_result(points_by_id[pid], metrics, {})
            except (GuardrailViolation, KeyError, TypeError, ValueError):
                dropped += 1
                continue
            completed[pid] = rec["result"]
        elif kind == "failed":
            pid = rec.get("point_id")
            if pid in known_ids:
                failed[pid] = {"error": rec.get("error", ""),
                               "attempts": rec.get("attempts", 0)}
    return completed, failed, dropped


def _batch_first_attempts(chunk: list[CampaignPoint], nvdla_segs: list,
                          mesh, note) -> list[LaneMetrics] | None:
    """Precompute attempt-0 results for a point chunk as one vmapped
    (optionally mesh-sharded) lane program, pre-validated with the
    batched closed-form check.  Returns None — sequential fallback for
    the whole chunk — if the batch engine cannot run it (unsupported
    stride, inconsistent batch); per-point failures are impossible
    here because faults are injected downstream, in the per-point
    attempt loop."""
    try:
        results = run_batch(chunk, nvdla_segs, mesh=mesh)
        check_segment_totals_batch(
            accesses=[r.accesses for r in results],
            llc_hits=[r.llc_hits for r in results],
            dram_row_hits=[r.dram_row_hits for r in results],
            total_cycles=[r.total_cycles for r in results],
            drams=[p.dram.dram() for p in chunk],
            t_llc_hit=results[0].t_llc_hit if results else 20)
        return results
    except Exception as e:
        note(f"batch of {len(chunk)} points fell back to sequential: "
             f"{type(e).__name__}: {e}")
        return None


def run_campaign(spec: CampaignSpec, out_dir: str, *,
                 resume: bool = False, overwrite: bool = False,
                 policy: RetryPolicy | None = None,
                 hooks: PointHooks | None = None,
                 retry_failed: bool = False,
                 progress=None, mesh=None,
                 batch_points: int = 32) -> CampaignResult:
    """Run (or resume) a campaign into ``out_dir``.

    ``resume`` replays ``journal.jsonl`` and re-enqueues only
    missing/corrupt points; without it, an existing journal is an error
    unless ``overwrite`` discards it.  ``retry_failed`` also re-enqueues
    previously quarantined points.  ``hooks`` is the fault-injection /
    instrumentation seam; ``progress`` is an optional callable fed
    one-line status strings.

    ``batch_points`` caps how many points run as one vmapped lane
    program (1 = strictly sequential); ``mesh`` (see
    ``repro.launch.mesh.make_sweep_mesh``) shards each batch's lane
    axis across devices.  Batched or not, journals and manifests are
    bit-identical: batch results unstack into the same per-point
    records, attempt-0 faults still fire per point, and any retry runs
    through the sequential path.

    Raises nothing for point-level failures (they quarantine); journal
    mismatches and spec errors raise.  A ``BaseException`` escaping a
    hook (the fault injector's simulated process death) propagates —
    the journal is already consistent at every such instant.
    """
    policy = policy or RetryPolicy()
    hooks = hooks or PointHooks()
    note = progress or (lambda msg: None)
    os.makedirs(out_dir, exist_ok=True)
    journal = Journal(os.path.join(out_dir, JOURNAL_NAME))
    manifest_path = os.path.join(out_dir, MANIFEST_NAME)

    points = spec.expand()
    ids = [p.point_id for p in points]
    if len(set(ids)) != len(ids):
        raise ValueError("campaign spec expands to duplicate points")
    known_ids = set(ids)

    completed: dict[str, dict] = {}
    failed: dict[str, dict] = {}
    dropped = 0
    if os.path.exists(journal.path):
        if resume:
            completed, failed, dropped = _load_journal_state(
                journal, spec, known_ids)
            if retry_failed:
                failed = {}
        elif overwrite:
            os.remove(journal.path)
            if os.path.exists(manifest_path):
                os.remove(manifest_path)
        else:
            raise JournalError(
                f"{journal.path} already exists; pass resume=True to "
                "continue it or overwrite=True to discard it")
    if not os.path.exists(journal.path):
        journal.append({"kind": "spec", "spec": spec.to_dict(),
                        "spec_hash": spec.spec_hash})

    resumed = len(completed)
    pending = [p for p in points
               if p.point_id not in completed and p.point_id not in failed]
    note(f"campaign {spec.name}: {len(points)} points, "
         f"{resumed} resumed, {len(failed)} quarantined, "
         f"{len(pending)} to run"
         + (f", {dropped} corrupt journal lines dropped" if dropped else ""))

    # seed the cross-point guardrail history from resumed results
    families: dict = {}
    by_id = {p.point_id: p for p in points}
    for pid, record in completed.items():
        _record_family(by_id[pid], LaneMetrics.from_record(record),
                       families)

    executed = 0
    step = max(1, batch_points)
    for shard in shard_points(pending):
        nvdla_segs = shard[0].model.trace()   # one trace per lane bucket
        for lo in range(0, len(shard), step):
            chunk = shard[lo:lo + step]
            batch = (None if len(chunk) < 2 and mesh is None
                     else _batch_first_attempts(chunk, nvdla_segs,
                                                mesh, note))
            for idx, point in enumerate(chunk):
                pid = point.point_id
                last_err: Exception | None = None
                for attempt in range(policy.max_retries + 1):
                    if attempt:
                        time.sleep(policy.backoff(attempt - 1))
                    hooks.before_point(point, attempt)
                    # attempt 0 reuses the batch result; every retry
                    # recomputes sequentially so a bad batch lane can
                    # never poison a point twice
                    compute = ((lambda r=batch[idx]: r)
                               if batch is not None and attempt == 0
                               else None)
                    try:
                        result = _attempt(point, attempt, nvdla_segs,
                                          hooks, policy, compute)
                        validate_result(point, result, families)
                    except Exception as e:
                        last_err = e
                        note(f"point {pid} attempt {attempt} failed: "
                             f"{type(e).__name__}: {e}")
                        continue
                    journal.append({"kind": "point", "point_id": pid,
                                    "attempt": attempt,
                                    "result": result.to_record()})
                    hooks.after_append(point, journal)
                    completed[pid] = result.to_record()
                    _record_family(point, result, families)
                    executed += 1
                    last_err = None
                    break
                if last_err is not None:
                    info = {"error":
                            f"{type(last_err).__name__}: {last_err}",
                            "attempts": policy.max_retries + 1}
                    journal.append({"kind": "failed", "point_id": pid,
                                    **info})
                    hooks.after_append(point, journal)
                    failed[pid] = info
                    note(f"point {pid} quarantined after "
                         f"{info['attempts']} attempts")

    journal.append({"kind": "done",
                    "completed": len(completed), "failed": len(failed)})
    manifest = build_manifest(spec, completed, failed)
    atomic_write_json(manifest_path, manifest)
    note(f"campaign {spec.name}: {len(completed)}/{len(points)} completed, "
         f"{len(failed)} quarantined -> {manifest_path}")
    return CampaignResult(manifest=manifest, manifest_path=manifest_path,
                          executed=executed, resumed=resumed,
                          dropped_records=dropped, failed=failed)
