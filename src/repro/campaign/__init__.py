"""Fault-tolerant sweep-campaign orchestration (docs/campaigns.md).

``CampaignSpec`` expands (models x geometries x mixes x DRAM configs)
into content-hashed points; ``run_campaign`` executes them with
journaled manifests, resume, retry/timeout, and numeric guardrails —
sequentially, or as vmapped point batches sharded over a
``jax.sharding`` mesh (``mesh=``/``batch_points=``); results are typed
``LaneMetrics`` records; ``FaultInjector`` injects deterministic
crashes/hangs/NaNs/torn writes so tests can prove the whole thing
actually survives them.
"""
from repro.campaign.executor import (
    CampaignResult,
    GuardrailViolation,
    PointHooks,
    PointTimeout,
    RetryPolicy,
    run_batch,
    run_campaign,
    run_point,
    shard_points,
    validate_result,
)
from repro.campaign.faults import (
    Fault,
    FaultInjector,
    InjectedCrash,
    plan_from_indices,
)
from repro.campaign.manifest import (
    Journal,
    JournalError,
    atomic_write_json,
    build_manifest,
)
from repro.campaign.spec import (
    CampaignPoint,
    CampaignSpec,
    DRAMSpec,
    GeometrySpec,
    MixSpec,
    ModelSpec,
    example_spec,
    mixed_backend_spec,
)
from repro.core.sweep import LaneMetrics, MixConfig, SweepGrid
