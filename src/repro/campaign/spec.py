"""Declarative sweep-campaign specs with content-addressed points.

A campaign is the cross product

    models x LLC geometries x co-runner mixes x DRAM configs

expanded into a *deterministic* list of ``CampaignPoint``s: same spec,
same point list, same order, and every point carries a stable
``point_id`` — a content hash of exactly the parameters that determine
its result (never wall-clock, host names, or execution order).  The
executor (``repro.campaign.executor``) journals completed points by id,
so a resumed campaign can decide what is already done without trusting
anything but the spec and the journal; a spec edit that changes any
point's physics changes that point's id and forces a re-run.

Specs round-trip through JSON (``CampaignSpec.to_dict``/``from_dict``)
so campaign files can live in the repo and in CI.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json

from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig

SPEC_VERSION = 1

_WSS_CHOICES = ("l1", "llc", "dram")


_BACKENDS = ("nvdla", "npu")
# trace sources per backend: NVDLA replays the fixed-function conv
# pipeline's YOLOv3 streams; the NPU backend compiles any model-zoo
# GEMM workload (repro.core.npu.WORKLOADS)
_BACKEND_MODELS = {
    "nvdla": ("yolov3",),
    "npu": ("yolov3", "transformer_decode", "mamba2_decode",
            "whisper_encoder"),
}


@functools.lru_cache(maxsize=8)
def _model_trace(window_bursts, chunk_bursts, layer_index):
    from repro.core import traces

    if window_bursts is None:
        return traces.network_trace()
    return traces.default_dbb_window(max_bursts=window_bursts,
                                     chunk_bursts=chunk_bursts,
                                     layer_index=layer_index)


@functools.lru_cache(maxsize=8)
def _npu_trace(name, window_bursts, chunk_bursts, rows, cols):
    from repro.core import npu

    cfg = npu.NPUConfig(rows=rows, cols=cols)
    return npu.npu_chunks(npu.workload(name), cfg, chunk_bursts,
                          max_bursts=window_bursts)


def canonical_json(obj) -> str:
    """The one JSON encoding used for hashing and checksums: sorted
    keys, no whitespace — byte-stable across processes and runs."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def content_hash(obj) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """One DBB trace source on one accelerator backend.

    ``backend="nvdla"`` (the default) replays the fixed-function conv
    pipeline's YOLOv3 streams: ``window_bursts=None`` replays the whole
    network trace, an integer clips an arbiter-interleaved window of
    ``layer_index``'s streams (see ``repro.core.traces``).
    ``backend="npu"`` compiles the named model-zoo GEMM workload on a
    ``npu_rows x npu_cols`` weight-stationary systolic array
    (``repro.core.npu``) and windows its interleaved DBB stream the
    same way — both backends are just segment sources to the campaign.

    Axis fields hash only where they carry physics: the backend fields
    are dropped from ``to_dict`` at their NVDLA defaults (so every
    pre-backend ``point_id`` is unchanged) and ``layer_index`` is
    dropped for NPU points (the NPU has no NVDLA layer windows); to
    keep the hash faithful, a field that would be dropped must sit at
    its default — validated below."""
    name: str = "yolov3"
    window_bursts: int | None = 4096
    chunk_bursts: int = 16
    layer_index: int = 40
    backend: str = "nvdla"
    npu_rows: int = 16
    npu_cols: int = 16

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; campaign "
                             f"backends are: {_BACKENDS}")
        known = _BACKEND_MODELS[self.backend]
        if self.name not in known:
            raise ValueError(f"unknown model {self.name!r}; the "
                             f"{self.backend!r} trace sources are: {known}")
        if self.window_bursts is not None and self.window_bursts <= 0:
            raise ValueError("window_bursts must be positive or None "
                             f"(whole frame), got {self.window_bursts}")
        if self.backend == "nvdla":
            if (self.npu_rows, self.npu_cols) != (16, 16):
                raise ValueError(
                    "npu_rows/npu_cols only apply to backend='npu' "
                    "(they are excluded from NVDLA point hashes, so a "
                    "non-default value would be silently ignored)")
        else:
            if self.npu_rows <= 0 or self.npu_cols <= 0:
                raise ValueError(f"NPU grid must be positive, got "
                                 f"{self.npu_rows}x{self.npu_cols}")
            if self.layer_index != 40:
                raise ValueError(
                    "layer_index only applies to backend='nvdla' (it is "
                    "excluded from NPU point hashes, so a non-default "
                    "value would be silently ignored)")

    def trace(self):
        # memoized: the window is a pure function of the (frozen) spec,
        # and the executor asks for it once per lane shard — callers
        # must treat the returned segment list as read-only
        if self.backend == "npu":
            return _npu_trace(self.name, self.window_bursts,
                              self.chunk_bursts, self.npu_rows,
                              self.npu_cols)
        return _model_trace(self.window_bursts, self.chunk_bursts,
                            self.layer_index)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.backend == "nvdla":
            # pre-backend hash compatibility: NVDLA dicts are exactly
            # what they were before the backend axis existed
            del d["backend"], d["npu_rows"], d["npu_cols"]
        else:
            del d["layer_index"]
        return d


@dataclasses.dataclass(frozen=True)
class GeometrySpec:
    """LLC geometry.  ``ways=None`` applies the Fig. 5 grid rule
    (``repro.core.soc.llc_config_for``); an explicit ``ways`` pins the
    associativity, which also lets campaigns build constant-``sets``
    families where LRU inclusion makes hit counts provably monotone in
    ways (the executor's cross-point guardrail)."""
    size_kib: float
    block: int = 64
    ways: int | None = None

    def __post_init__(self):
        if self.size_kib <= 0 or self.block <= 0:
            raise ValueError(f"geometry must be positive, got "
                             f"size_kib={self.size_kib} block={self.block}")
        if self.ways is not None and self.ways <= 0:
            raise ValueError(f"ways must be positive, got {self.ways}")

    def llc(self) -> LLCConfig:
        if self.ways is None:
            from repro.core.soc import llc_config_for

            return llc_config_for(self.size_kib, self.block)
        return LLCConfig(size_bytes=int(self.size_kib * 1024),
                         ways=self.ways, block_bytes=self.block)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class MixSpec:
    """Co-runner mix: ``corunners`` BwWrite streams with working-set
    size class ``wss`` interleaved into the lane (Fig. 6 semantics)."""
    corunners: int = 0
    wss: str = "l1"

    def __post_init__(self):
        if self.corunners < 0:
            raise ValueError(f"corunners must be >= 0, got {self.corunners}")
        if self.wss not in _WSS_CHOICES:
            raise ValueError(f"wss must be one of {_WSS_CHOICES}, "
                             f"got {self.wss!r}")

    def mix(self):
        """The core-engine ``repro.core.sweep.MixConfig`` this spec
        describes (the same late-bound pattern as ``GeometrySpec.llc``)."""
        from repro.core.sweep import MixConfig

        return MixConfig(corunners=self.corunners, wss=self.wss)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DRAMSpec:
    banks: int = 32
    row_bytes: int = 2048
    t_cas_cycles: int = 14
    t_rcd_cycles: int = 14
    t_rp_cycles: int = 14

    def __post_init__(self):
        if self.banks <= 0 or self.row_bytes <= 0:
            raise ValueError(f"DRAM geometry must be positive, got "
                             f"banks={self.banks} row_bytes={self.row_bytes}")

    def dram(self) -> DRAMConfig:
        return DRAMConfig(banks=self.banks, row_bytes=self.row_bytes,
                          t_cas_cycles=self.t_cas_cycles,
                          t_rcd_cycles=self.t_rcd_cycles,
                          t_rp_cycles=self.t_rp_cycles)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class CampaignPoint:
    """One (model, geometry, mix, dram) simulation.  ``point_id`` hashes
    the physics-determining parameters plus ``SPEC_VERSION`` so result
    records are self-describing and spec edits invalidate exactly the
    points they change."""
    model: ModelSpec
    geometry: GeometrySpec
    mix: MixSpec
    dram: DRAMSpec

    def params(self) -> dict:
        return {"spec_version": SPEC_VERSION,
                "model": self.model.to_dict(),
                "geometry": self.geometry.to_dict(),
                "mix": self.mix.to_dict(),
                "dram": self.dram.to_dict()}

    @property
    def point_id(self) -> str:
        return content_hash(self.params())


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    name: str
    models: tuple[ModelSpec, ...] = (ModelSpec(),)
    geometries: tuple[GeometrySpec, ...] = (GeometrySpec(2048),)
    mixes: tuple[MixSpec, ...] = (MixSpec(),)
    drams: tuple[DRAMSpec, ...] = (DRAMSpec(),)

    def __post_init__(self):
        if not (self.models and self.geometries and self.mixes
                and self.drams):
            raise ValueError("a campaign needs at least one model, "
                             "geometry, mix, and DRAM config")
        for d in self.drams:
            for g in self.geometries:
                if d.row_bytes % g.block:
                    raise ValueError(
                        f"DRAM row_bytes {d.row_bytes} is not a multiple "
                        f"of LLC block {g.block}: the segment-native "
                        "pipeline needs whole blocks per row (see "
                        "socsim.simulate_dbb_segments)")

    def expand(self) -> list[CampaignPoint]:
        """The deterministic point list: models (outer) x drams x mixes
        x geometries (inner), exactly the spec's declared order."""
        return [CampaignPoint(m, g, x, d)
                for m in self.models for d in self.drams
                for x in self.mixes for g in self.geometries]

    @property
    def spec_hash(self) -> str:
        return content_hash(self.to_dict())

    def to_dict(self) -> dict:
        return {"spec_version": SPEC_VERSION, "name": self.name,
                "models": [m.to_dict() for m in self.models],
                "geometries": [g.to_dict() for g in self.geometries],
                "mixes": [x.to_dict() for x in self.mixes],
                "drams": [d.to_dict() for d in self.drams]}

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignSpec":
        version = d.get("spec_version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise ValueError(f"campaign spec version {version} is not "
                             f"supported (this build speaks {SPEC_VERSION})")
        return cls(
            name=d["name"],
            models=tuple(ModelSpec(**m) for m in d.get(
                "models", [{}])) or (ModelSpec(),),
            geometries=tuple(GeometrySpec(**g)
                             for g in d["geometries"]),
            mixes=tuple(MixSpec(**x) for x in d.get("mixes", [{}])),
            drams=tuple(DRAMSpec(**x) for x in d.get("drams", [{}])))

    @classmethod
    def load(cls, path: str) -> "CampaignSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")


def example_spec(points: int = 8, *, window_bursts: int = 512,
                 name: str = "example") -> CampaignSpec:
    """A tiny but real campaign for smoke tests and CI: one windowed
    YOLOv3 trace, a same-``sets`` geometry family (so the monotone-ways
    guardrail is live), and solo + contended mixes, sized to exactly
    ``points`` points."""
    if not 0 < points <= 16:
        raise ValueError(f"example spec supports 1..16 points, got {points}")
    n_mixes = 2 if points % 2 == 0 and points >= 4 else 1
    n_geoms = points // n_mixes
    sets = 64
    geoms = tuple(GeometrySpec(size_kib=sets * (1 << i) * 64 / 1024,
                               block=64, ways=1 << i)
                  for i in range(n_geoms))
    mixes = (MixSpec(0, "l1"), MixSpec(2, "llc"))[:n_mixes]
    return CampaignSpec(
        name=name,
        models=(ModelSpec(window_bursts=window_bursts),),
        geometries=geoms, mixes=mixes)


def mixed_backend_spec(points: int = 8, *, window_bursts: int = 512,
                       name: str = "mixed-backends") -> CampaignSpec:
    """An NVDLA + NPU head-to-head campaign for smoke tests and CI:
    the same windowed YOLOv3 frame traced by both backends across a
    same-``sets`` geometry family, so every guardrail (including
    monotone-ways, which groups by model) runs per backend."""
    if points % 2 or not 0 < points <= 16:
        raise ValueError(f"mixed spec needs an even 2..16 points, "
                         f"got {points}")
    sets = 64
    geoms = tuple(GeometrySpec(size_kib=sets * (1 << i) * 64 / 1024,
                               block=64, ways=1 << i)
                  for i in range(points // 2))
    return CampaignSpec(
        name=name,
        models=(ModelSpec(window_bursts=window_bursts),
                ModelSpec(window_bursts=window_bursts, backend="npu",
                          npu_rows=8, npu_cols=8)),
        geometries=geoms)
