from repro.campaign.cli import main

raise SystemExit(main())
