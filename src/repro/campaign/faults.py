"""Deterministic fault injection for campaign runs.

The executor's crash-safety claims are only worth what the tests can
prove, and the tests can only prove what they can *inject*.  This
module wraps the executor's ``PointHooks`` seam with a scheduled fault
plan:

* ``crash``  — raise ``InjectedCrash`` (a ``BaseException``, so the
  executor's retry logic cannot swallow it) before the point runs:
  the simulated hard kill of a worker process;
* ``hang``   — sleep past the per-point timeout inside the worker:
  a wedged simulation that must be timed out and retried;
* ``nan``    — poison a float field of an otherwise-complete result:
  the classic silently-diverged lane the guardrails must catch;
* ``corrupt``— deflate the hit counters *consistently* (total recomputed
  so the closed-form identity still holds): only the cross-point
  LRU-inclusion monotonicity guardrail can catch this one;
* ``torn``   — after the point's journal record is appended, truncate
  the journal mid-record and crash: the torn-write the checksummed
  replay must detect and re-enqueue.

Every fault fires exactly once: firings are journaled (append + fsync)
to ``faults_consumed.jsonl`` in the campaign directory *before* the
fault takes effect, so a resumed run — a fresh "process" — does not
re-fire faults it already delivered.  That makes a faulted campaign a
deterministic function of (spec, plan): the equivalence tests demand
the final manifest be bit-identical to a clean run's.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time

from repro.campaign.executor import PointHooks
from repro.campaign.manifest import Journal
from repro.campaign.spec import CampaignSpec

FAULT_KINDS = ("crash", "hang", "nan", "corrupt", "torn")


class InjectedCrash(BaseException):
    """Simulated process death.  Derives from ``BaseException`` so no
    retry/quarantine path can absorb it — exactly like a SIGKILL."""


@dataclasses.dataclass(frozen=True)
class Fault:
    point_id: str
    kind: str
    attempt: int = 0          # fire on this attempt number only
    hang_s: float = 1.0       # sleep length for "hang"
    field: str = "hit_rate"   # poisoned field for "nan"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")

    @property
    def key(self) -> str:
        return f"{self.point_id}:{self.kind}:{self.attempt}"


def plan_from_indices(spec: CampaignSpec,
                      entries: list[dict]) -> list[Fault]:
    """Build a fault plan from spec-order point indices — the JSON shape
    the CLI's ``--inject`` file uses: ``[{"point": 3, "kind": "crash",
    "attempt": 0, ...}, ...]``."""
    points = spec.expand()
    faults = []
    for e in entries:
        idx = e["point"]
        if not 0 <= idx < len(points):
            raise ValueError(f"fault point index {idx} outside the "
                             f"{len(points)}-point campaign")
        faults.append(Fault(
            point_id=points[idx].point_id, kind=e["kind"],
            attempt=int(e.get("attempt", 0)),
            hang_s=float(e.get("hang_s", 1.0)),
            field=str(e.get("field", "hit_rate"))))
    return faults


def _consistent_deflate(result, dram_cfg):
    """Zero the hit counters of a ``LaneMetrics`` but keep the
    closed-form latency identity intact (every access a miss, every
    miss a row miss) — internally consistent, globally wrong: only the
    cross-point monotonicity guardrail can catch it."""
    acc = result.accesses
    return dataclasses.replace(
        result,
        llc_hits=0,
        dram_row_hits=0,
        hit_rate=0.0,
        nvdla_hits=0,
        nvdla_hit_rate=0.0,
        nvdla_misses=result.nvdla_accesses,
        nvdla_miss_row_hits=0,
        nvdla_miss_row_hit_rate=0.0,
        total_cycles=(acc * result.t_llc_hit + acc * dram_cfg.t_cas_cycles
                      + acc * (dram_cfg.t_rp_cycles
                               + dram_cfg.t_rcd_cycles)))


class FaultInjector(PointHooks):
    """PointHooks implementation driven by a deterministic fault plan.

    ``consumed_path`` (default ``<out_dir>/faults_consumed.jsonl``)
    records delivered faults durably before they take effect; pass the
    same plan to every resume attempt and each fault still fires once
    across the whole campaign lifetime."""

    def __init__(self, faults: list[Fault], out_dir: str, *,
                 consumed_name: str = "faults_consumed.jsonl"):
        os.makedirs(out_dir, exist_ok=True)
        self.faults = list(faults)
        self.path = os.path.join(out_dir, consumed_name)
        self._consumed: set[str] = set()
        if os.path.exists(self.path):
            with open(self.path) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        try:
                            self._consumed.add(json.loads(line)["key"])
                        except (json.JSONDecodeError, KeyError):
                            continue   # torn tail of the consumed log

    def _due(self, point, attempt: int | None, kinds: tuple[str, ...]):
        """Next unconsumed fault for this (point, attempt, kind set);
        ``attempt=None`` matches any attempt."""
        for fault in self.faults:
            if (fault.point_id == point.point_id
                    and (attempt is None or fault.attempt == attempt)
                    and fault.kind in kinds
                    and fault.key not in self._consumed):
                return fault
        return None

    def _consume(self, fault: Fault) -> None:
        """Durably mark a fault delivered *before* it takes effect —
        the injector survives its own crashes the same way the
        executor does."""
        self._consumed.add(fault.key)
        with open(self.path, "a") as f:
            f.write(json.dumps({"key": fault.key}) + "\n")
            f.flush()
            os.fsync(f.fileno())

    # -- PointHooks ------------------------------------------------------
    def before_point(self, point, attempt: int) -> None:
        fault = self._due(point, attempt, ("crash",))
        if fault is not None:
            self._consume(fault)
            raise InjectedCrash(f"injected crash before point "
                                f"{point.point_id} attempt {attempt}")

    def in_worker(self, point, attempt: int, run):
        fault = self._due(point, attempt, ("hang",))
        if fault is not None:
            self._consume(fault)
            time.sleep(fault.hang_s)
        result = run()
        fault = self._due(point, attempt, ("nan",))
        if fault is not None:
            self._consume(fault)
            result = dataclasses.replace(result,
                                         **{fault.field: math.nan})
        fault = self._due(point, attempt, ("corrupt",))
        if fault is not None:
            self._consume(fault)
            result = _consistent_deflate(result, point.dram.dram())
        return result

    def after_append(self, point, journal: Journal) -> None:
        fault = self._due(point, None, ("torn",))
        if fault is not None:
            self._consume(fault)
            size = os.path.getsize(journal.path)
            with open(journal.path, "rb+") as f:
                f.truncate(max(0, size - 17))   # tear into the record
            raise InjectedCrash(f"injected torn write after point "
                                f"{point.point_id}")
