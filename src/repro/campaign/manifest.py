"""Journaled campaign manifests: checksummed JSONL + atomic snapshots.

Two durability layers, matching how a run farm actually fails:

* ``Journal`` — an append-only JSONL file, one self-checksummed record
  per line (``crc`` = crc32 of the record's canonical JSON without the
  ``crc`` field), flushed and fsync'd per append.  A crash can tear at
  most the final line, and ``replay`` detects exactly that: a line that
  fails to parse or whose checksum mismatches is *dropped and counted*,
  never trusted, so the executor re-enqueues the affected point instead
  of resuming from a half-written result.
* ``atomic_write_json`` — write-temp-then-fsync-then-rename for the
  final ``manifest.json`` snapshot (and any other whole-file artifact):
  readers see either the old complete file or the new complete file,
  never a prefix.

The final manifest is a pure function of (spec, completed results,
failed points) with point records in spec order — deliberately free of
wall-clock and host details so an interrupted-then-resumed campaign is
bit-identical to an uninterrupted one (the fault-injection tests
diff the bytes).
"""
from __future__ import annotations

import json
import os
import zlib

from repro.campaign.spec import CampaignSpec, canonical_json

JOURNAL_NAME = "journal.jsonl"
MANIFEST_NAME = "manifest.json"

RECORD_KINDS = ("spec", "point", "failed", "done")


def record_crc(record: dict) -> int:
    """Checksum of a journal record, excluding its own ``crc`` field."""
    payload = {k: v for k, v in record.items() if k != "crc"}
    return zlib.crc32(canonical_json(payload).encode())


def fsync_dir(path: str) -> None:
    """Flush a directory entry (the rename itself) to stable storage.
    A no-op on filesystems that refuse O_RDONLY directory fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write_json(path: str, obj, *, indent: int | None = 2) -> None:
    """Write ``obj`` as JSON such that ``path`` is always either absent,
    the previous complete file, or the new complete file."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=indent, sort_keys=True)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)))


class JournalError(RuntimeError):
    """The journal cannot be used at all (e.g. a different campaign's
    journal is already in the output directory)."""


class Journal:
    """Append-only JSONL journal with per-record checksums."""

    def __init__(self, path: str):
        self.path = path

    def append(self, record: dict, *, fsync: bool = True) -> None:
        if record.get("kind") not in RECORD_KINDS:
            raise ValueError(f"unknown journal record kind: "
                             f"{record.get('kind')!r}")
        record = dict(record)
        record["crc"] = record_crc(record)
        line = canonical_json(record) + "\n"
        with open(self.path, "a") as f:
            f.write(line)
            f.flush()
            if fsync:
                os.fsync(f.fileno())

    def replay(self) -> tuple[list[dict], int]:
        """Parse the journal, returning (valid records, dropped lines).

        Torn or corrupt lines — unparseable JSON, missing/mismatching
        ``crc``, unknown kind — are dropped and counted; everything
        that checks out is returned in append order."""
        if not os.path.exists(self.path):
            return [], 0
        records, dropped = [], 0
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    dropped += 1
                    continue
                if (not isinstance(rec, dict)
                        or rec.get("kind") not in RECORD_KINDS
                        or rec.get("crc") != record_crc(rec)):
                    dropped += 1
                    continue
                records.append(rec)
        return records, dropped


def build_manifest(spec: CampaignSpec, completed: dict[str, dict],
                   failed: dict[str, dict]) -> dict:
    """The final, deterministic campaign manifest.

    Point records appear in *spec* order regardless of execution or
    journal order; no timestamps, attempt counts, or host details enter
    — those live in the journal.  Completed-point ``result`` dicts are
    included verbatim (they round-trip exactly through JSON)."""
    points, failed_points = [], []
    for point in spec.expand():
        pid = point.point_id
        if pid in completed:
            points.append({"point_id": pid, "params": point.params(),
                           "result": completed[pid]})
        elif pid in failed:
            failed_points.append({"point_id": pid,
                                  "params": point.params(),
                                  **failed[pid]})
    return {
        "campaign": spec.name,
        "spec": spec.to_dict(),
        "spec_hash": spec.spec_hash,
        "counts": {"total": len(spec.expand()),
                   "completed": len(points),
                   "failed": len(failed_points)},
        "points": points,
        "failed_points": failed_points,
    }
