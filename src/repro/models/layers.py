"""Shared model building blocks: norms, RoPE, MLPs, embeddings.

All modules are pure functions over explicit ``Param`` pytrees.  Compute
happens in ``cfg.dtype`` (bf16 by default) with fp32 accumulations where it
matters (norm statistics, softmax, loss).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, pad_to
from repro.sharding import logical_constraint
from repro.types import Param

VOCAB_PAD_MULTIPLE = 128  # lcm(TPU lane width, max model-axis size)


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def padded_vocab(cfg: ModelConfig) -> int:
    return pad_to(cfg.vocab_size, VOCAB_PAD_MULTIPLE)


# --------------------------------------------------------------------------
# initialisers
# --------------------------------------------------------------------------
def _dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return jax.random.normal(key, shape, dtype) * scale


def init_norm(cfg: ModelConfig) -> dict:
    p = {"scale": Param(jnp.ones((cfg.d_model,), jnp.float32), ("norm",))}
    if cfg.use_layer_norm:
        p["bias"] = Param(jnp.zeros((cfg.d_model,), jnp.float32), ("norm",))
    return p


def apply_norm(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.use_layer_norm:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"] + params["bias"]
    else:  # RMSNorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * params["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings (fraction<1 => partial rotary, chatglm-style)
# --------------------------------------------------------------------------
def rope_dim(cfg: ModelConfig) -> int:
    d = int(cfg.head_dim * cfg.rope_fraction)
    return d - (d % 2)


def rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple:
    """positions (...,) -> cos/sin of shape (..., dim//2)."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions broadcastable to (..., seq)."""
    rd = rope_dim(cfg)
    if rd == 0:
        return x
    cos, sin = rope_angles(positions, rd, cfg.rope_theta)
    cos = cos[..., None, :]  # (..., seq, 1, rd//2)
    sin = sin[..., None, :]
    rot, rest = x[..., :rd], x[..., rd:]
    x1, x2 = rot[..., : rd // 2], rot[..., rd // 2 :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), rest], axis=-1)


# --------------------------------------------------------------------------
# MLP (gated SwiGLU/GeGLU or plain 2-matrix)
# --------------------------------------------------------------------------
def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_mlp(key, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": Param(_dense_init(k1, (d, ff), d), ("embed", "mlp")),
        "w_out": Param(_dense_init(k2, (ff, d), ff), ("mlp", "embed")),
    }
    if cfg.gated_mlp:
        p["w_gate"] = Param(_dense_init(k3, (d, ff), d), ("embed", "mlp"))
    if not cfg.gated_mlp and cfg.attn_bias:  # whisper-style biased MLP
        p["b_in"] = Param(jnp.zeros((ff,), jnp.float32), ("mlp",))
        p["b_out"] = Param(jnp.zeros((d,), jnp.float32), ("norm",))
    return p


def apply_mlp(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(dt))
    if "b_in" in params:
        h = h + params["b_in"].astype(dt)
    h = _act(cfg.act)(h)
    if cfg.gated_mlp:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
        h = h * g
    h = logical_constraint(h, "act_batch", "act_seq", "act_mlp")
    out = jnp.einsum("...f,fd->...d", h, params["w_out"].astype(dt))
    if "b_out" in params:
        out = out + params["b_out"].astype(dt)
    return out


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------
def init_embeddings(key, cfg: ModelConfig) -> dict:
    v = padded_vocab(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embed": Param(_dense_init(k1, (v, cfg.d_model), cfg.d_model),
                        ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        p["unembed"] = Param(
            _dense_init(k2, (cfg.d_model, v), cfg.d_model), ("embed", "vocab")
        )
    return p


def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = compute_dtype(cfg)
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    if cfg.family == "hybrid":  # gemma-style sqrt(d) embedding scale
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    return logical_constraint(x, "act_batch", "act_seq", "act_embed")


def unembed(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Returns fp32 logits over the *padded* vocab, padding masked to -inf."""
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"].astype(dt))
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["unembed"].astype(dt))
    logits = logits.astype(jnp.float32)
    logits = logical_constraint(logits, "act_batch", "act_seq", "act_vocab")
    v, vp = cfg.vocab_size, padded_vocab(cfg)
    if vp != v:
        mask = jnp.arange(vp) < v
        logits = jnp.where(mask, logits, -1e9)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean CE in fp32. logits (..., V), labels (...)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
