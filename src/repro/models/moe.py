"""Top-k mixture-of-experts FFN (Mixtral / Grok-1 style).

GShard-style dense dispatch: tokens are grouped, each group routes its
tokens into per-expert capacity slots with one-hot dispatch/combine
einsums.  This formulation is differentiable, partitions cleanly under
pjit (group dim shards over data), and its dispatch FLOPs are a small
fraction (~E*C/(6*ff*topk)) of the expert GEMMs themselves.

Expert weights are FSDP-sharded on d_model (data axis) and
tensor-parallel on d_ff (model axis); the expert dimension (8) stays
unsharded because it does not divide the 16-way axes of the assigned
production mesh (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _act, _dense_init
from repro.sharding import logical_constraint
from repro.types import Param

MOE_GROUP = 512  # tokens per routing group


def init_moe(key, cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": Param(_dense_init(ks[0], (d, e), d), ("embed", "experts")),
        "w_in": Param(_dense_init(ks[1], (e, d, ff), d), ("experts", "embed", "mlp")),
        "w_gate": Param(_dense_init(ks[2], (e, d, ff), d), ("experts", "embed", "mlp")),
        "w_out": Param(_dense_init(ks[3], (e, ff, d), ff), ("experts", "mlp", "embed")),
    }


def _capacity(group_size: int, cfg: ModelConfig) -> int:
    c = int(group_size * cfg.num_experts_per_tok * cfg.moe_capacity_factor
            / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def apply_moe(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    dt = x.dtype
    b, s, d = x.shape
    tokens = b * s
    group = MOE_GROUP if tokens % MOE_GROUP == 0 and tokens > MOE_GROUP else tokens
    g = tokens // group
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    c = _capacity(group, cfg)

    xg = x.reshape(g, group, d)
    xg = logical_constraint(xg, "act_batch", None, "act_embed")

    # --- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg, params["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (g, t, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)         # renormalise top-k

    # --- capacity assignment ------------------------------------------------
    # position of each (token, k) within its expert queue, in token order
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (g, t, k, e)
    # priority: k=0 choices first, then k=1 (GShard policy)
    flat = onehot.swapaxes(1, 2).reshape(g, k * group, e)       # (g, k*t, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat             # (g, k*t, e)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).astype(jnp.int32)  # (g, k*t)
    fits = (pos < c) & (jnp.max(flat, axis=-1) > 0)
    keep = jnp.argmax(flat, axis=-1)                            # expert id per entry
    # build (g, k*t, e, c) one-hot in compute dtype to bound memory
    slot_oh = (jax.nn.one_hot(keep, e, dtype=dt)
               * fits[..., None].astype(dt))[..., None] \
        * jax.nn.one_hot(pos, c, dtype=dt)[:, :, None, :]
    # (g, k*t, e, c) -> (g, t, k, e, c)
    slot_oh = slot_oh.reshape(g, k, group, e, c).swapaxes(1, 2)

    gates = gate_vals.astype(dt)[..., None, None] * slot_oh     # (g, t, k, e, c)
    combine = jnp.sum(gates, axis=2)                            # (g, t, e, c)
    dispatch = (combine > 0).astype(dt)

    # --- expert compute -------------------------------------------------------
    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xg)            # (g, e, c, d)
    xin = logical_constraint(xin, "act_batch", "act_experts", None, "act_embed")
    h = jnp.einsum("gecd,edf->gecf", xin, params["w_in"].astype(dt))
    hg = jnp.einsum("gecd,edf->gecf", xin, params["w_gate"].astype(dt))
    h = _act(cfg.act)(h) * hg
    h = logical_constraint(h, "act_batch", "act_experts", None, "act_mlp")
    out = jnp.einsum("gecf,efd->gecd", h, params["w_out"].astype(dt))
    y = jnp.einsum("gtec,gecd->gtd", combine, out)              # weighted scatter-back
    return y.reshape(b, s, d)


def load_balance_loss(probs: jax.Array, expert_idx: jax.Array, cfg: ModelConfig):
    """Switch-style auxiliary loss (mean prob * mean assignment fraction)."""
    e = cfg.num_experts
    onehot = jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32)
    frac = jnp.mean(onehot, axis=tuple(range(onehot.ndim - 1)))
    mean_prob = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(frac * mean_prob)
