"""Model assembly: heterogeneous block stacks, scan-over-layers, caches.

One code path drives all ten assigned architectures:

* ``block_pattern`` (e.g. ``("rec", "rec", "attn")``) is cycled over
  ``num_layers``; layers are grouped into ``num_layers // len(pattern)``
  *pattern groups* whose parameters are stacked and scanned with
  ``lax.scan`` (keeps lowered HLO small for 512-device compiles), the
  remainder layers are applied unrolled.
* MoE families swap the dense MLP for the top-k expert layer.
* ``encdec`` (whisper) adds an encoder stack and per-decoder-layer
  cross-attention; the modality frontend is a stub — the batch supplies
  precomputed frame embeddings.
* ``vlm`` (internvl) prepends precomputed patch embeddings to the token
  embeddings; the ViT is a stub per the assignment.

Parameter trees are ``Param``-wrapped (logical axes for the sharding
resolver); all ``apply_*`` paths take plain value trees.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.sharding import logical_constraint
from repro.types import Param, map_params


# --------------------------------------------------------------------------
# structure helpers
# --------------------------------------------------------------------------
def pattern_split(cfg: ModelConfig) -> tuple[tuple[str, ...], int, int]:
    """(pattern, n_full_groups, n_remainder_layers)."""
    pat = cfg.block_pattern
    n_full, rem = divmod(cfg.num_layers, len(pat))
    return pat, n_full, rem


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """Absolute sinusoidal embedding (whisper-style stub positions)."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(1, half - 1))
    ang = positions.astype(jnp.float32)[:, None] * freq[None, :]
    out = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    if d % 2:
        out = jnp.pad(out, ((0, 0), (0, 1)))
    return out


def _attn_window(cfg: ModelConfig) -> int:
    return cfg.sliding_window or cfg.local_window


# --------------------------------------------------------------------------
# per-block init / apply
# --------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, kind: str, *, decoder_cross: bool = False):
    ks = jax.random.split(key, 3)
    if kind == "ssm":
        return {"norm1": L.init_norm(cfg), "ssm": ssm_mod.init_ssm(ks[0], cfg)}
    if kind == "rec":
        return {
            "norm1": L.init_norm(cfg),
            "rec": rglru_mod.init_rglru(ks[0], cfg),
            "norm2": L.init_norm(cfg),
            "mlp": L.init_mlp(ks[1], cfg),
        }
    # attention block (dense / moe / encdec-decoder)
    p = {
        "norm1": L.init_norm(cfg),
        "attn": attn_mod.init_attention(ks[0], cfg),
        "norm2": L.init_norm(cfg),
        "mlp": (moe_mod.init_moe(ks[1], cfg) if cfg.num_experts
                else L.init_mlp(ks[1], cfg)),
    }
    if decoder_cross:
        p["norm_x"] = L.init_norm(cfg)
        p["xattn"] = attn_mod.init_attention(ks[2], cfg)
    return p


def _apply_ffn(params, x, cfg: ModelConfig):
    if cfg.num_experts:
        return moe_mod.apply_moe(params, x, cfg)
    return L.apply_mlp(params, x, cfg)


def apply_block(params, x, cfg: ModelConfig, kind: str, *, positions,
                causal: bool = True, enc_out=None, collect_cache: bool = False):
    """Full-sequence block. Returns (x, cache_or_None)."""
    cache = None
    if kind == "ssm":
        h = L.apply_norm(params["norm1"], x, cfg)
        if collect_cache:
            y, cache = ssm_mod.apply_ssm(params["ssm"], h, cfg, return_state=True)
        else:
            y = ssm_mod.apply_ssm(params["ssm"], h, cfg)
        return x + y, cache
    if kind == "rec":
        h = L.apply_norm(params["norm1"], x, cfg)
        if collect_cache:
            y, cache = rglru_mod.apply_rglru(params["rec"], h, cfg, return_state=True)
        else:
            y = rglru_mod.apply_rglru(params["rec"], h, cfg)
        x = x + y
        x = x + _apply_ffn(params["mlp"], L.apply_norm(params["norm2"], x, cfg), cfg)
        return x, cache
    # attention
    h = L.apply_norm(params["norm1"], x, cfg)
    window = _attn_window(cfg)
    if collect_cache:
        y, (k, v) = attn_mod.attend(
            params["attn"], h, cfg, positions=positions, causal=causal,
            window=window, return_kv=True)
        cache = {"k": k, "v": v}
    else:
        y = attn_mod.attend(params["attn"], h, cfg, positions=positions,
                            causal=causal, window=window)
    x = x + y
    if "xattn" in params:
        hx = L.apply_norm(params["norm_x"], x, cfg)
        if collect_cache:
            yx, (kx, vx) = attn_mod.attend(
                params["xattn"], hx, cfg, positions=positions, causal=False,
                kv_src=enc_out, return_kv=True)
            cache = {"self": cache, "cross": {"k": kx, "v": vx}}
        else:
            yx = attn_mod.attend(params["xattn"], hx, cfg, positions=positions,
                                 causal=False, kv_src=enc_out)
        x = x + yx
    x = x + _apply_ffn(params["mlp"], L.apply_norm(params["norm2"], x, cfg), cfg)
    return x, cache


def apply_block_decode(params, x, cfg: ModelConfig, kind: str, cache, t):
    """One-token block step. Returns (x, new_cache)."""
    if kind == "ssm":
        h = L.apply_norm(params["norm1"], x, cfg)
        y, new_cache = ssm_mod.apply_ssm_decode(params["ssm"], h, cfg, cache)
        return x + y, new_cache
    if kind == "rec":
        h = L.apply_norm(params["norm1"], x, cfg)
        y, new_cache = rglru_mod.apply_rglru_decode(params["rec"], h, cfg, cache)
        x = x + y
        x = x + _apply_ffn(params["mlp"], L.apply_norm(params["norm2"], x, cfg), cfg)
        return x, new_cache
    h = L.apply_norm(params["norm1"], x, cfg)
    window = _attn_window(cfg)
    self_cache = cache["self"] if "self" in cache else cache
    y, new_self = attn_mod.attend_decode(params["attn"], h, cfg, self_cache, t,
                                         window=window)
    x = x + y
    new_cache = new_self
    if "xattn" in params:
        cross = cache["cross"]
        hx = L.apply_norm(params["norm_x"], x, cfg)
        yx, _ = attn_mod.attend_decode(params["xattn"], hx, cfg, None, t,
                                       cross_cache=cross)
        x = x + yx
        new_cache = {"self": new_self, "cross": cross}
    x = x + _apply_ffn(params["mlp"], L.apply_norm(params["norm2"], x, cfg), cfg)
    return x, new_cache


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _stack_blocks(key, cfg: ModelConfig, pattern, n_full: int, *,
                  decoder_cross: bool = False):
    """Tuple (one entry per pattern position) of stacked block params."""
    out = []
    for j, kind in enumerate(pattern):
        kj = jax.random.fold_in(key, j)
        keys = jax.random.split(kj, n_full)
        stacked = jax.vmap(
            lambda k: init_block(k, cfg, kind, decoder_cross=decoder_cross)
        )(keys)
        stacked = map_params(lambda p: Param(p.value, ("layers",) + p.axes), stacked)
        out.append(stacked)
    return tuple(out)


def init_params(key, cfg: ModelConfig) -> dict:
    """Param-wrapped model parameters (use jax.eval_shape for abstract init)."""
    pattern, n_full, rem = pattern_split(cfg)
    k_emb, k_blocks, k_rem, k_enc = jax.random.split(key, 4)
    decoder_cross = cfg.is_encoder_decoder
    p: dict = {
        "embed": L.init_embeddings(k_emb, cfg),
        "final_norm": L.init_norm(cfg),
    }
    if n_full:
        p["blocks"] = _stack_blocks(k_blocks, cfg, pattern, n_full,
                                    decoder_cross=decoder_cross)
    if rem:
        p["rem"] = tuple(
            init_block(jax.random.fold_in(k_rem, j), cfg, pattern[j % len(pattern)],
                       decoder_cross=decoder_cross)
            for j in range(rem)
        )
    if cfg.is_encoder_decoder:
        ne = cfg.num_encoder_layers
        p["encoder"] = {
            "blocks": _stack_blocks(k_enc, cfg, ("attn",), ne),
            "final_norm": L.init_norm(cfg),
        }
    return p


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill / encoder)
# --------------------------------------------------------------------------
def _run_stack(params, x, cfg: ModelConfig, pattern, *, positions, causal,
               enc_out=None, remat: bool, collect_cache: bool = False):
    """Scan the stacked pattern groups then the remainder layers.

    Returns (x, caches) where caches mirrors {"blocks": tuple, "rem": tuple}
    (entries None unless collect_cache).
    """

    def group_fn(x, group):
        new_caches = []
        for j, kind in enumerate(pattern):
            x, c = apply_block(group[j], x, cfg, kind, positions=positions,
                               causal=causal, enc_out=enc_out,
                               collect_cache=collect_cache)
            new_caches.append(c)
        x = logical_constraint(x, "act_batch", "act_seq", "act_embed")
        return x, tuple(new_caches)

    body = group_fn
    if remat:
        body = jax.checkpoint(group_fn, prevent_cse=False)

    caches: dict = {}
    if "blocks" in params:
        x, caches["blocks"] = jax.lax.scan(body, x, params["blocks"],
                                           unroll=cfg.unroll_scans)
    if "rem" in params:
        rem_caches = []
        for j, blk in enumerate(params["rem"]):
            kind = pattern[j % len(pattern)]
            x, c = apply_block(blk, x, cfg, kind, positions=positions,
                               causal=causal, enc_out=enc_out,
                               collect_cache=collect_cache)
            rem_caches.append(c)
        caches["rem"] = tuple(rem_caches)
    return x, caches


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (B, T, d)."""
    dt = L.compute_dtype(cfg)
    x = frames.astype(dt)
    pos = jnp.arange(frames.shape[1])
    x = x + _sinusoid(pos, cfg.d_model).astype(dt)[None]
    x = logical_constraint(x, "act_batch", "act_seq", "act_embed")
    x, _ = _run_stack(params["encoder"], x, cfg, ("attn",), positions=pos,
                      causal=False, remat=False)
    return L.apply_norm(params["encoder"]["final_norm"], x, cfg)


def _embed_input(params, batch: dict, cfg: ModelConfig):
    """Token (+patch/frame) embedding. Returns (x, positions, n_prefix)."""
    x = L.embed_tokens(params["embed"], batch["tokens"], cfg)
    n_prefix = 0
    if cfg.family == "vlm" and "patches" in batch:
        patches = batch["patches"].astype(x.dtype)
        n_prefix = patches.shape[1]
        x = jnp.concatenate([patches, x], axis=1)
    positions = jnp.arange(x.shape[1])
    if cfg.is_encoder_decoder:  # no RoPE — absolute sinusoid (stub positions)
        x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)[None]
    return x, positions, n_prefix


def forward(params, batch: dict, cfg: ModelConfig, *, mode: str = "train"):
    """Full-sequence logits (B, S_tokens, padded_vocab) in fp32."""
    pattern, _, _ = pattern_split(cfg)
    x, positions, n_prefix = _embed_input(params, batch, cfg)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, batch["frames"], cfg)
    remat = (mode == "train") and cfg.remat == "layer"
    x, _ = _run_stack(params, x, cfg, pattern, positions=positions,
                      causal=True, enc_out=enc_out, remat=remat)
    x = L.apply_norm(params["final_norm"], x, cfg)
    if n_prefix:
        x = x[:, n_prefix:]
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch: dict, cfg: ModelConfig):
    logits = forward(params, batch, cfg, mode="train")
    loss = L.cross_entropy(logits, batch["labels"])
    acc = jnp.mean(
        (jnp.argmax(logits, axis=-1) == batch["labels"]).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}
