"""RG-LRU recurrent block (Griffin / recurrentgemma).

[arXiv:2402.19427]  The recurrent block is:

    y  = W_out( RG-LRU(conv1d(W_x x)) * gelu(W_y x) )

and the Real-Gated Linear Recurrent Unit itself, per channel:

    r_t = sigmoid(W_a u_t + b_a)           (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)           (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)  with c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The full-sequence path computes the linear recurrence with
``jax.lax.associative_scan`` (log-depth, parallel over batch/width); decode
is a single fused step.  Gate projections use full (w, w) matrices (the
reference uses block-diagonal per-head matrices; a dense matrix is a strict
superset and shards cleanly over the `model` axis — noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init
from repro.sharding import logical_constraint
from repro.types import Param

RGLRU_C = 8.0


def init_rglru(key, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.rglru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    # Lambda init so that a = exp(-c*softplus(L)) is distributed in
    # (0.9, 0.999), the Griffin init range.
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u ** (1.0 / RGLRU_C))))  # softplus^-1
    return {
        "w_x": Param(_dense_init(ks[0], (d, w), d), ("embed", "rglru")),
        "w_y": Param(_dense_init(ks[1], (d, w), d), ("embed", "rglru")),
        "conv_w": Param(
            jax.random.normal(ks[2], (cfg.rglru_conv, w), jnp.float32)
            * (cfg.rglru_conv ** -0.5), ("conv", "rglru")),
        "conv_b": Param(jnp.zeros((w,), jnp.float32), ("rglru",)),
        "w_a": Param(_dense_init(ks[3], (w, w), w), ("rglru_in", "rglru")),
        "b_a": Param(jnp.zeros((w,), jnp.float32), ("rglru",)),
        "w_i": Param(_dense_init(ks[4], (w, w), w), ("rglru_in", "rglru")),
        "b_i": Param(jnp.zeros((w,), jnp.float32), ("rglru",)),
        "lam": Param(lam, ("rglru",)),
        "w_out": Param(_dense_init(ks[0], (w, d), w), ("rglru", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d (no activation). x (B, L, C); w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b


def _gates(params, u: jax.Array):
    """u (..., w) -> (log_a, gated_input), both fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(uf @ params["w_i"].astype(jnp.float32) + params["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r      # <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalisation (Griffin eq. 4)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * uf)


def rglru_scan(params, u: jax.Array) -> jax.Array:
    """Full-sequence RG-LRU. u (B, L, w) -> (B, L, w) fp32 recurrence."""
    a, b = _gates(params, u)                                   # (B, L, w) each

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype)


def rglru_step(params, u: jax.Array, h_prev: jax.Array):
    """Single decode step. u (B, w); h_prev (B, w) fp32 -> (y, h_new)."""
    a, b = _gates(params, u)
    h = a * h_prev + b
    return h.astype(u.dtype), h


def apply_rglru(params: dict, x: jax.Array, cfg: ModelConfig, *,
                return_state: bool = False):
    """Full recurrent block. x (B, L, d) -> (B, L, d) [, cache]."""
    dt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, params["w_y"].astype(dt)))
    u_raw = jnp.einsum("bld,dw->blw", x, params["w_x"].astype(dt))
    u = _causal_conv(u_raw, params["conv_w"].astype(dt), params["conv_b"].astype(dt))
    u = logical_constraint(u, "act_batch", "act_seq", "act_rglru")
    a, b = _gates(params, u)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h_all = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h_all.astype(u.dtype)
    y = h * gate
    y = logical_constraint(y, "act_batch", "act_seq", "act_rglru")
    out = jnp.einsum("blw,wd->bld", y, params["w_out"].astype(dt))
    if return_state:
        k = cfg.rglru_conv
        tail = u_raw[:, -(k - 1):, :] if u_raw.shape[1] >= k - 1 else jnp.pad(
            u_raw, ((0, 0), (k - 1 - u_raw.shape[1], 0), (0, 0)))
        cache = {"conv": tail.astype(jnp.bfloat16),
                 "h": h_all[:, -1, :].astype(jnp.float32)}
        return out, cache
    return out


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_rglru_cache(cfg: ModelConfig, batch: int, *, abstract: bool = False):
    w = cfg.rglru_width or cfg.d_model
    conv_shape = (batch, cfg.rglru_conv - 1, w)
    h_shape = (batch, w)
    if abstract:
        return {"conv": jax.ShapeDtypeStruct(conv_shape, jnp.bfloat16),
                "h": jax.ShapeDtypeStruct(h_shape, jnp.float32)}
    return {"conv": jnp.zeros(conv_shape, jnp.bfloat16),
            "h": jnp.zeros(h_shape, jnp.float32)}


def rglru_cache_axes() -> dict:
    return {"conv": ("act_batch", None, "act_rglru"),
            "h": ("act_batch", "act_rglru")}


def apply_rglru_decode(params: dict, x: jax.Array, cfg: ModelConfig, cache: dict):
    """One-token step. x (B, 1, d) -> (y (B, 1, d), new_cache)."""
    dt = x.dtype
    x0 = x[:, 0, :]
    gate = jax.nn.gelu(x0 @ params["w_y"].astype(dt))
    u_new = x0 @ params["w_x"].astype(dt)                       # (B, w)
    hist = jnp.concatenate([cache["conv"].astype(dt), u_new[:, None, :]], axis=1)
    conv_w = params["conv_w"].astype(dt)
    u = jnp.einsum("bkc,kc->bc", hist, conv_w) + params["conv_b"].astype(dt)
    y, h_new = rglru_step(params, u, cache["h"])
    out = (y * gate) @ params["w_out"].astype(dt)
    new_cache = {"conv": hist[:, 1:, :].astype(cache["conv"].dtype), "h": h_new}
    return out[:, None, :], new_cache
