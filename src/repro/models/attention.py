"""Attention: MHA/GQA/MQA, sliding-window (banded), cross-attn, KV caches.

Implementation notes
--------------------
* Query-chunked "memory-efficient" attention for train/prefill: a
  ``lax.scan`` over query chunks keeps the score matrix at
  O(chunk x kv_span) instead of O(S^2) — required for the 32k prefill cells
  to fit HBM at the production mesh.
* Sliding-window attention is *banded*: each query chunk only reads the
  (window + chunk) key slice it can see, so SWA prefill is O(S*W) compute
  and memory, not O(S^2) with a mask.
* GQA is computed by logically expanding KV to the query heads (a broadcast,
  sliced per-device by the partitioner) so the head dimension shards over the
  full `model` axis even when num_kv_heads < |model|.
* Decode uses either a dense cache (full attention) or a rolling-buffer cache
  of length `window` (SWA / local attention), with RoPE applied at insert
  time (absolute positions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, apply_rope, compute_dtype
from repro.sharding import logical_constraint
from repro.types import Param

DEFAULT_Q_CHUNK = 1024


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> dict:
    d, nq, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": Param(_dense_init(ks[0], (d, nq, hd), d),
                    ("embed", "heads", "head_dim")),
        "wk": Param(_dense_init(ks[1], (d, nkv, hd), d),
                    ("embed", "kv_heads", "head_dim")),
        "wv": Param(_dense_init(ks[2], (d, nkv, hd), d),
                    ("embed", "kv_heads", "head_dim")),
        "wo": Param(_dense_init(ks[3], (nq, hd, d), nq * hd),
                    ("heads", "head_dim", "embed")),
    }
    if cfg.attn_bias:
        p["bq"] = Param(jnp.zeros((nq, hd), jnp.float32), ("heads", "head_dim"))
        p["bk"] = Param(jnp.zeros((nkv, hd), jnp.float32), ("kv_heads", "head_dim"))
        p["bv"] = Param(jnp.zeros((nkv, hd), jnp.float32), ("kv_heads", "head_dim"))
    return p


def _project_q(params, x, cfg: ModelConfig):
    dt = x.dtype
    q = jnp.einsum("...d,dnh->...nh", x, params["wq"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
    return q


def _project_kv(params, x, cfg: ModelConfig):
    dt = x.dtype
    k = jnp.einsum("...d,dnh->...nh", x, params["wk"].astype(dt))
    v = jnp.einsum("...d,dnh->...nh", x, params["wv"].astype(dt))
    if "bk" in params:
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    return k, v


def _expand_kv(k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B, S, n_kv, hd) -> (B, S, n_q, hd) by broadcasting each KV group."""
    group = cfg.num_heads // cfg.num_kv_heads
    if group == 1:
        return k
    b, s, nkv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, nkv, group, hd))
    return k.reshape(b, s, cfg.num_heads, hd)


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


# --------------------------------------------------------------------------
# train / prefill path (query-chunked)
# --------------------------------------------------------------------------
def _attend_chunk(q, k, v, qpos, kpos, *, causal, window, softcap, scale):
    """q (B,L,n,h); k/v (B,T,n,h); positions (L,), (T,) -> (B,L,n,h)."""
    scores = jnp.einsum("blnh,btnh->bnlt", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    mask &= kpos[None, :] >= 0
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bnlt,btnh->blnh", probs, v)


def attend(params: dict, x: jax.Array, cfg: ModelConfig, *,
           positions: jax.Array, causal: bool = True, window: int = 0,
           kv_src: jax.Array | None = None,
           kv_positions: jax.Array | None = None,
           q_chunk: int = DEFAULT_Q_CHUNK,
           return_kv: bool = False):
    """Full-sequence attention (training / prefill / encoder / cross).

    x: (B, S, d). kv_src: encoder output for cross-attention (B, T, d).
    positions: (S,) query positions. Returns (B, S, d) [, (k, v)].
    """
    scale = cfg.head_dim ** -0.5
    if cfg.attn_q_chunk:
        q_chunk = cfg.attn_q_chunk
    q = _project_q(params, x, cfg)
    q = logical_constraint(q, "act_batch", "act_seq", "act_heads", None)
    src = x if kv_src is None else kv_src
    k, v = _project_kv(params, src, cfg)
    if kv_positions is None:
        kv_positions = positions if kv_src is None else jnp.arange(src.shape[1])
    if cfg.rope_fraction > 0 and kv_src is None:
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, kv_positions, cfg)
    kv_out = (k, v)
    k = _expand_kv(k, cfg)
    v = _expand_kv(v, cfg)
    k = logical_constraint(k, "act_batch", "act_seq", "act_heads", None)
    v = logical_constraint(v, "act_batch", "act_seq", "act_heads", None)

    b, s = x.shape[0], x.shape[1]
    t = src.shape[1]
    if s % q_chunk != 0 or s <= q_chunk:
        q_chunk = s
    n_chunks = s // q_chunk
    banded = (bool(window) and kv_src is None
              and (window + q_chunk) <= t and n_chunks > 1)

    # Per-chunk remat: the backward pass recomputes scores/probs instead of
    # storing the O(chunk x kv_span) fp32 score matrices of every chunk —
    # the flash-attention memory behaviour, expressed at the JAX level (the
    # Pallas SWA kernel is the TPU-native realisation of the same policy).
    chunk_fn = jax.checkpoint(
        functools.partial(_attend_chunk, causal=causal, window=window,
                          softcap=cfg.attn_logit_softcap, scale=scale),
        prevent_cse=False)

    if n_chunks == 1:
        out = chunk_fn(q, k, v, positions, kv_positions)
    else:
        qc = q.reshape(b, n_chunks, q_chunk, *q.shape[2:]).swapaxes(0, 1)
        pc = positions.reshape(n_chunks, q_chunk)
        span = window + q_chunk if banded else t

        def body(_, inp):
            qi, qpos_i, idx = inp
            if banded:
                start = jnp.clip(idx * q_chunk - window, 0, t - span)
                ki = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
                vi = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
                kpos_i = start + jnp.arange(span)
            else:
                ki, vi, kpos_i = k, v, kv_positions
            oi = chunk_fn(qi, ki, vi, qpos_i, kpos_i)
            return None, oi

        _, oc = jax.lax.scan(body, None, (qc, pc, jnp.arange(n_chunks)),
                             unroll=cfg.unroll_scans)
        out = oc.swapaxes(0, 1).reshape(b, s, cfg.num_heads, cfg.head_dim)

    out = logical_constraint(out, "act_batch", "act_seq", "act_heads", None)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
    if return_kv:
        return y, kv_out
    return y


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------
def _splitk_shards(cfg: ModelConfig, cache_len: int) -> int:
    """Split-K shard count when the cache is sequence-sharded.

    When the active sharding rules map ``cache_seq`` to a mesh axis (the
    flash-decoding layout — required when num_kv_heads doesn't divide the
    tensor-parallel degree, e.g. grok's kv=8 on a 16-way `model` axis),
    decode attention must be computed as per-shard partial softmax with a
    small stat-combine, or XLA all-gathers the whole cache per token."""
    from repro.sharding import active_rules

    r = active_rules()
    if r is None:
        return 0
    axes = r.rules.get("cache_seq", ())
    ns = 1
    for ax in axes:
        if ax in r.mesh.axis_names:
            ns *= r.mesh_axis_size(ax)
    if ns > 1 and cache_len % ns == 0:
        return ns
    return 0


def _attend_decode_splitk(q, k, v, t, cfg: ModelConfig, ns: int, scale):
    """q (B,1,nq,hd); k/v (B,S,nq,hd) seq-sharded -> (B,1,nq,hd).

    Reshapes S into (ns, S/ns) so the shard axis is explicit; partials are
    local, the combine is an O(B*nq*hd) reduction over `ns` (an all-reduce
    of KB, not an all-gather of the GB-scale cache)."""
    b, s, nq, hd = k.shape
    c = s // ns
    kr = k.reshape(b, ns, c, nq, hd)
    vr = v.reshape(b, ns, c, nq, hd)
    kr = logical_constraint(kr, "act_batch", "cache_seq", None, None, None)
    vr = logical_constraint(vr, "act_batch", "cache_seq", None, None, None)
    kpos = (jnp.arange(ns)[:, None] * c + jnp.arange(c)[None, :])  # (ns, c)
    valid = kpos <= t

    scores = jnp.einsum("blnh,bscnh->bsnc", q, kr,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, cfg.attn_logit_softcap)
    scores = jnp.where(valid[None, :, None, :], scores, -1e30)
    m_i = jnp.max(scores, axis=-1)                       # (B, ns, nq)
    p = jnp.exp(scores - m_i[..., None])
    l_i = jnp.sum(p, axis=-1)                            # (B, ns, nq)
    o_i = jnp.einsum("bsnc,bscnh->bsnh", p.astype(q.dtype), vr)

    # combine over the sharded ns axis (tiny all-reduces under SPMD)
    m = jnp.max(m_i, axis=1, keepdims=True)              # (B, 1, nq)
    w = jnp.exp(m_i - m)                                 # (B, ns, nq)
    denom = jnp.sum(w * l_i, axis=1)                     # (B, nq)
    num = jnp.sum(w[..., None] * o_i.astype(jnp.float32), axis=1)
    out = num / jnp.maximum(denom, 1e-30)[..., None]
    return out[:, None].astype(q.dtype)                  # (B, 1, nq, hd)


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                    window: int = 0, abstract: bool = False):
    """Dense cache (window=0) or rolling-buffer cache of length `window`."""
    length = min(window, max_len) if window else max_len
    shape = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else compute_dtype(cfg)

    def mk(shp, d):
        return jax.ShapeDtypeStruct(shp, d) if abstract else jnp.zeros(shp, d)

    cache = {"k": mk(shape, dt), "v": mk(shape, dt)}
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1]
        cache["k_scale"] = mk(sshape, jnp.float32)
        cache["v_scale"] = mk(sshape, jnp.float32)
    return cache


def cache_axes() -> dict:
    kv = ("act_batch", "cache_seq", "act_kv_heads", None)
    return {"k": kv, "v": kv, "k_scale": kv[:-1], "v_scale": kv[:-1]}


def _quant_kv(x: jax.Array):
    """(.., hd) -> int8 values + per-leading scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_kv(q: jax.Array, scale: jax.Array, dt) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dt)


def attend_decode(params: dict, x: jax.Array, cfg: ModelConfig, cache: dict,
                  t: jax.Array, *, window: int = 0,
                  cross_cache: dict | None = None):
    """One-token decode. x: (B, 1, d); t: scalar current position.

    Returns (y, new_cache).  With `cross_cache` set, performs cross-attention
    against the precomputed encoder KV instead (cache is passed through).
    """
    scale = cfg.head_dim ** -0.5
    q = _project_q(params, x, cfg)  # (B, 1, nq, hd)
    if cross_cache is not None:
        k, v = cross_cache["k"], cross_cache["v"]
        kpos = jnp.arange(k.shape[1])
        valid = jnp.ones((k.shape[1],), bool)
        new_cache = cache
    else:
        k_new, v_new = _project_kv(params, x, cfg)
        if cfg.rope_fraction > 0:
            q = apply_rope(q, t[None] if t.ndim == 0 else t, cfg)
            k_new = apply_rope(k_new, t[None] if t.ndim == 0 else t, cfg)
        length = cache["k"].shape[1]
        quant = cfg.kv_cache_dtype == "int8"
        ns = _splitk_shards(cfg, length) if not window else 0
        slot = (t % length) if window else t
        writes = {}
        if quant:
            writes["k"], writes["k_scale"] = _quant_kv(k_new)
            writes["v"], writes["v_scale"] = _quant_kv(v_new)
        else:
            writes["k"], writes["v"] = k_new, v_new
        new_cache = {}
        for name, val in writes.items():
            buf = cache[name]
            if ns:
                # sequence-sharded cache: a dynamic-update-slice on the
                # sharded dim makes SPMD gather the cache — use an
                # elementwise select-write instead (local on every shard)
                sel = jnp.arange(length) == slot
                sel = sel.reshape((1, length) + (1,) * (buf.ndim - 2))
                new_cache[name] = jnp.where(sel, val.astype(buf.dtype), buf)
            else:
                new_cache[name] = jax.lax.dynamic_update_slice_in_dim(
                    buf, val.astype(buf.dtype), slot, axis=1)
        if quant:
            k = _dequant_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
            v = _dequant_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
        else:
            k, v = new_cache["k"], new_cache["v"]
        idx = jnp.arange(length)
        if window:
            # slot i holds absolute position p_i = t - ((t - i) mod length)
            kpos = t - jnp.mod(t - idx, length)
            valid = (kpos >= 0) & (t - kpos < window)
        else:
            kpos = idx
            valid = idx <= t
        if ns:
            out = _attend_decode_splitk(q, _expand_kv(k, cfg),
                                        _expand_kv(v, cfg), t, cfg, ns, scale)
            y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
            return y, new_cache

    k = _expand_kv(k, cfg)
    v = _expand_kv(v, cfg)
    scores = jnp.einsum("blnh,btnh->bnlt", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, cfg.attn_logit_softcap)
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bnlt,btnh->blnh", probs, v)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache
