"""Model zoo public API."""
from repro.models.decoding import (  # noqa: F401
    DecodeWorkingSet,
    cache_slot_axes,
    decode_step,
    decode_working_set,
    init_caches,
    prefill,
    slot_decode_step,
)
from repro.models.transformer import (  # noqa: F401
    forward,
    init_params,
    loss_fn,
    pattern_split,
)
