"""Model zoo public API."""
from repro.models.decoding import (  # noqa: F401
    decode_step,
    init_caches,
    prefill,
)
from repro.models.transformer import (  # noqa: F401
    forward,
    init_params,
    loss_fn,
    pattern_split,
)
