"""KV/state cache construction, prefill, and single-token decode.

Cache layout mirrors the parameter layout: ``{"blocks": tuple(stacked per
pattern position), "rem": tuple(per remainder layer)}`` so the same
``lax.scan`` drives both.  Cache leaves are ``Param``-wrapped (logical axes)
so the sharding resolver produces ``in_shardings`` for ``serve_step`` the
same way it does for parameters.

Cache kinds
-----------
* attention, full context  — dense ``(B, cache_len, n_kv, hd)`` ring written
  at absolute slots;
* attention, windowed (SWA / local) — rolling buffer of ``min(window,
  cache_len)`` slots, slot = position mod length;
* mamba-2 — ``(B, conv_k-1, C)`` conv tail + ``(B, H, N, P)`` SSM state;
* RG-LRU — conv tail + ``(B, W)`` hidden state;
* whisper decoder — ``{"self": dense KV, "cross": precomputed encoder KV}``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.transformer import (
    _attn_window,
    _embed_input,
    _run_stack,
    _sinusoid,
    apply_block_decode,
    encode,
    pattern_split,
)
from repro.types import Param, map_params


# --------------------------------------------------------------------------
# cache init
# --------------------------------------------------------------------------
def _wrap(values, axes) -> dict:
    """Zip a cache value dict against an axes dict into Param leaves."""
    out = {}
    for k, v in values.items():
        out[k] = _wrap(v, axes[k]) if isinstance(v, dict) else Param(v, axes[k])
    return out


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                 *, abstract: bool):
    if kind == "ssm":
        return _wrap(ssm_mod.init_ssm_cache(cfg, batch, abstract=abstract),
                     ssm_mod.ssm_cache_axes())
    if kind == "rec":
        return _wrap(rglru_mod.init_rglru_cache(cfg, batch, abstract=abstract),
                     rglru_mod.rglru_cache_axes())
    window = _attn_window(cfg)
    val = attn_mod.init_attn_cache(cfg, batch, cache_len, window=window,
                                   abstract=abstract)
    cache = _wrap(val, attn_mod.cache_axes())
    if cfg.is_encoder_decoder:
        shape = (batch, cfg.encoder_len, cfg.num_kv_heads, cfg.head_dim)
        dt = L.compute_dtype(cfg)
        mk = (lambda: jax.ShapeDtypeStruct(shape, dt)) if abstract else (
            lambda: jnp.zeros(shape, dt))
        cross = _wrap({"k": mk(), "v": mk()}, attn_mod.cache_axes())
        cache = {"self": cache, "cross": cross}
    return cache


def _stack_cache(tree, n: int):
    def one(p: Param):
        v = p.value
        if isinstance(v, jax.ShapeDtypeStruct):
            sv = jax.ShapeDtypeStruct((n,) + v.shape, v.dtype)
        else:
            sv = jnp.broadcast_to(v[None], (n,) + v.shape)
        return Param(sv, ("layers",) + p.axes)

    return map_params(one, tree)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, *,
                abstract: bool = False) -> dict:
    """Param-wrapped cache pytree for `decode_step` (strip with param_values)."""
    pattern, n_full, rem = pattern_split(cfg)
    caches: dict = {}
    if n_full:
        caches["blocks"] = tuple(
            _stack_cache(
                _layer_cache(cfg, kind, batch, cache_len, abstract=abstract),
                n_full)
            for kind in pattern
        )
    if rem:
        caches["rem"] = tuple(
            _layer_cache(cfg, pattern[j % len(pattern)], batch, cache_len,
                         abstract=abstract)
            for j in range(rem)
        )
    return caches


# --------------------------------------------------------------------------
# prefill: full-sequence forward that also fills the caches
# --------------------------------------------------------------------------
def _to_decode_cache(raw, cfg: ModelConfig, kind: str, cache_len: int,
                     positions: jax.Array):
    """Convert raw prefill cache (per layer) to the decode cache format."""
    if kind in ("ssm", "rec"):
        return raw  # already {"conv": tail, "state"/"h": final}

    def convert_kv(raw_kv):
        k, v = raw_kv["k"], raw_kv["v"]
        window = _attn_window(cfg)
        length = min(window, cache_len) if window else cache_len
        s = k.shape[1]
        take = min(s, length)
        slots = jnp.mod(positions[-take:], length)
        buf_k = jnp.zeros((k.shape[0], length) + k.shape[2:], k.dtype)
        buf_v = jnp.zeros_like(buf_k)
        buf_k = buf_k.at[:, slots].set(k[:, -take:])
        buf_v = buf_v.at[:, slots].set(v[:, -take:])
        if cfg.kv_cache_dtype == "int8":
            qk, sk = attn_mod._quant_kv(buf_k)
            qv, sv = attn_mod._quant_kv(buf_v)
            return {"k": qk, "k_scale": sk, "v": qv, "v_scale": sv}
        return {"k": buf_k, "v": buf_v}

    if cfg.is_encoder_decoder:
        return {"self": convert_kv(raw["self"]), "cross": raw["cross"]}
    return convert_kv(raw)


def prefill(params, batch: dict, cfg: ModelConfig, cache_len: int):
    """Run the full prompt, return (last-token logits (B, Vp), caches, t_next).

    ``batch`` is the same structure as training (tokens + frames/patches);
    caches come back as plain value trees in decode format.
    """
    pattern, _, _ = pattern_split(cfg)
    x, positions, n_prefix = _embed_input(params, batch, cfg)
    enc_out = encode(params, batch["frames"], cfg) if cfg.is_encoder_decoder else None
    x, raw = _run_stack(params, x, cfg, pattern, positions=positions,
                        causal=True, enc_out=enc_out, remat=False,
                        collect_cache=True)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]

    caches: dict = {}
    if "blocks" in raw and raw["blocks"]:
        converted = []
        for j, kind in enumerate(pattern):
            conv = jax.vmap(
                lambda r: _to_decode_cache(r, cfg, kind, cache_len, positions)
            )(raw["blocks"][j])
            converted.append(conv)
        caches["blocks"] = tuple(converted)
    if "rem" in raw and raw["rem"]:
        caches["rem"] = tuple(
            _to_decode_cache(raw["rem"][j], cfg, pattern[j % len(pattern)],
                             cache_len, positions)
            for j in range(len(raw["rem"]))
        )
    t_next = jnp.asarray(x.shape[1], jnp.int32)
    return logits, caches, t_next


# --------------------------------------------------------------------------
# single-token decode
# --------------------------------------------------------------------------
def decode_step(params, caches, token: jax.Array, t: jax.Array,
                cfg: ModelConfig):
    """One decode step.  token (B, 1) int32; t scalar absolute position.

    Returns (logits (B, padded_vocab) fp32, new_caches).
    """
    pattern, _, _ = pattern_split(cfg)
    x = L.embed_tokens(params["embed"], token, cfg)
    if cfg.is_encoder_decoder:
        pos = _sinusoid(t[None] if t.ndim == 0 else t, cfg.d_model)
        x = x + pos.astype(x.dtype)[None]

    new_caches: dict = {}
    if "blocks" in caches:
        def body(x, inp):
            group, group_cache = inp
            new_group = []
            for j, kind in enumerate(pattern):
                x, c = apply_block_decode(group[j], x, cfg, kind,
                                          group_cache[j], t)
                new_group.append(c)
            return x, tuple(new_group)

        x, new_caches["blocks"] = jax.lax.scan(
            body, x, (params["blocks"], caches["blocks"]),
            unroll=cfg.unroll_scans)
    if "rem" in caches:
        rem_new = []
        for j, blk in enumerate(params["rem"]):
            kind = pattern[j % len(pattern)]
            x, c = apply_block_decode(blk, x, cfg, kind, caches["rem"][j], t)
            rem_new.append(c)
        new_caches["rem"] = tuple(rem_new)

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, new_caches


# --------------------------------------------------------------------------
# per-slot decode: independent positions per batch row
# --------------------------------------------------------------------------
def cache_slot_axes(caches) -> dict:
    """Per-leaf batch-axis tree for the decode cache pytree.

    ``blocks`` leaves are layer-stacked (layers, B, ...) so their slot
    axis is 1; ``rem`` leaves are batch-leading.  The returned tree has
    the same structure as ``caches`` with ints at the leaves — usable
    directly as ``vmap`` in/out axes or to locate the slot axis when
    scattering prefill rows into an engine's slot caches."""
    return {k: jax.tree_util.tree_map(lambda _: 1 if k == "blocks" else 0, v)
            for k, v in caches.items()}


def slot_decode_step(params, caches, tokens: jax.Array, ts: jax.Array,
                     cfg: ModelConfig):
    """One decode step with an *independent position per row*.

    ``tokens`` (B, 1) int32, ``ts`` (B,) int32 absolute positions.  Each
    row runs the batch-1 ``decode_step`` under ``jax.vmap`` over the
    cache slot axis, so rows at heterogeneous sequence lengths advance
    in one kernel — the continuous-batching decode kernel.  Bit-identical
    to ``decode_step`` when all positions agree (tests/test_serve.py).

    Returns (logits (B, padded_vocab) fp32, new_caches).
    """
    axes = cache_slot_axes(caches)

    def one(cache, token, t):
        cache = {k: jax.tree_util.tree_map(
                    lambda x, kk=k: jnp.expand_dims(x, 1 if kk == "blocks"
                                                    else 0), v)
                 for k, v in cache.items()}
        lg, nc = decode_step(params, cache, token[None], t, cfg)
        nc = {k: jax.tree_util.tree_map(
                 lambda x, kk=k: (x[:, 0] if kk == "blocks" else x[0]), v)
              for k, v in nc.items()}
        return lg[0], nc

    return jax.vmap(one, in_axes=(axes, 0, 0), out_axes=(0, axes))(
        caches, tokens, ts)


# --------------------------------------------------------------------------
# decode working set: the byte model behind the serving latency oracle
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DecodeWorkingSet:
    """Per-step memory working set of one decoding sequence.

    ``kv_entries`` is (window, per_token_bytes) per decoder layer —
    window 0 means the full context is live (dense attention), a
    positive window caps the rolling buffer.  ``state_bytes`` is the
    length-independent per-step read set (SSM/RG-LRU recurrent state,
    conv tails, whisper cross-attention KV).  ``weight_bytes`` is the
    streamed parameter footprint per step (every active parameter is
    read once per decoded token)."""
    weight_bytes: int
    kv_entries: tuple[tuple[int, int], ...]
    state_bytes: int

    def kv_bytes(self, tokens: int) -> int:
        """Live KV bytes read by one decode step at sequence length
        ``tokens`` (windowed layers cap at their buffer)."""
        return sum((min(tokens, w) if w else tokens) * per
                   for w, per in self.kv_entries)

    @property
    def kv_token_bytes(self) -> int:
        """Marginal KV bytes appended per decoded token (block-sizing
        rate for the paged allocator; windowed layers recycle slots but
        the pool accounts their peak via ``kv_bytes``)."""
        return sum(per for _, per in self.kv_entries)


def decode_working_set(cfg: ModelConfig) -> DecodeWorkingSet:
    """Byte-level working set of one decode step, mirroring the cache
    layout ``init_caches`` builds (same windows, dtypes, int8 scales).

    This is what ``repro.serve.oracle`` lowers to DBB segment traces:
    weights stream once per step, each sequence re-reads its live KV,
    and recurrent/cross state is a constant per-step read."""
    dt_bytes = jnp.dtype(L.compute_dtype(cfg)).itemsize
    window = _attn_window(cfg)
    kv_entries = []
    state = 0
    for kind in cfg.layer_kinds():
        if kind == "ssm":
            conv = (cfg.ssm_conv - 1) * ssm_mod._conv_channels(cfg) * 2
            ssm = cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_head_dim * 4
            state += conv + ssm
            continue
        if kind == "rec":
            w = cfg.rglru_width or cfg.d_model
            state += (cfg.rglru_conv - 1) * w * 2 + w * 4
            continue
        # attention: K + V per cached token (+ int8 scales)
        if cfg.kv_cache_dtype == "int8":
            per = 2 * cfg.num_kv_heads * (cfg.head_dim + 4)
        else:
            per = 2 * cfg.num_kv_heads * cfg.head_dim * dt_bytes
        kv_entries.append((window, per))
        if cfg.is_encoder_decoder:   # precomputed cross KV, read each step
            state += (2 * cfg.encoder_len * cfg.num_kv_heads
                      * cfg.head_dim * dt_bytes)
    return DecodeWorkingSet(
        weight_bytes=int(cfg.active_param_count() * dt_bytes),
        kv_entries=tuple(kv_entries),
        state_bytes=int(state))
