"""Mamba-2 block: state-space duality (SSD), chunked matmul formulation.

[arXiv:2405.21060]  The SSD layer computes, per head h with state size N:

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T        (N x P state)
    y_t = C_t^T h_t + D x_t

The chunked algorithm splits L into chunks of Q tokens; within a chunk the
contribution is a masked (C B^T ⊙ decay) matmul (MXU-friendly); across
chunks a short ``lax.scan`` carries the (H, N, P) state.  This file is the
pure-jnp path; ``repro.kernels.ssd`` provides the Pallas TPU kernel for the
intra-chunk part and is numerically checked against this implementation.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init
from repro.sharding import logical_constraint
from repro.types import Param


def _conv_channels(cfg: ModelConfig) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_ssm(key, cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.ssm_d_inner
    h, n, g = cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_ngroups
    proj_out = 2 * di + 2 * g * n + h
    ks = jax.random.split(key, 4)
    return {
        "in_proj": Param(_dense_init(ks[0], (d, proj_out), d), ("embed", "ssm_inner")),
        "conv_w": Param(
            jax.random.normal(ks[1], (cfg.ssm_conv, _conv_channels(cfg)), jnp.float32)
            / math.sqrt(cfg.ssm_conv), ("conv", "ssm_inner")),
        "conv_b": Param(jnp.zeros((_conv_channels(cfg),), jnp.float32), ("ssm_inner",)),
        "A_log": Param(jnp.log(jnp.linspace(1.0, 16.0, h)), ("ssm_heads",)),
        "dt_bias": Param(jnp.zeros((h,), jnp.float32), ("ssm_heads",)),
        "D": Param(jnp.ones((h,), jnp.float32), ("ssm_heads",)),
        "norm_scale": Param(jnp.ones((di,), jnp.float32), ("ssm_inner",)),
        "out_proj": Param(_dense_init(ks[2], (di, d), di), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x (B, L, C); w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(y + b)


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    di, gn, h = cfg.ssm_d_inner, cfg.ssm_ngroups * cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn :]
    assert dt.shape[-1] == h
    return z, xbc, dt


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int, unroll: bool = False):
    """SSD scan in chunked (matmul) form.

    x (Bb, L, H, P); dt (Bb, L, H) [post-softplus]; A (H,) negative;
    B, C (Bb, L, G, N); D (H,).  Returns y (Bb, L, H, P).
    """
    bb, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    q = chunk if l % chunk == 0 and l > chunk else l
    nc = l // q

    xc = x.reshape(bb, nc, q, h, p)
    dtc = dt.reshape(bb, nc, q, h)
    bc = B.reshape(bb, nc, q, g, n)
    cc = C.reshape(bb, nc, q, g, n)

    dta = dtc * A  # (Bb, nc, q, h) log-decay increments (negative)
    cum = jnp.cumsum(dta, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (Bb,nc,l,s,h)
    causal = jnp.tril(jnp.ones((q, q), bool))
    # mask *inside* the exp: exp of a large positive (non-causal) seg would
    # produce inf whose where-gradient is NaN
    seg = jnp.where(causal[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)

    # intra-chunk: (C_l . B_s) * decay(l,s) * dt_s
    cb = jnp.einsum("bclgn,bcsgn->bcgls", cc, bc)             # (Bb,nc,g,l,s)
    cb = cb.reshape(bb, nc, g, 1, q, q)
    dec = decay.reshape(bb, nc, q, q, g, hg).transpose(0, 1, 4, 5, 2, 3)
    dts = dtc.reshape(bb, nc, q, g, hg).transpose(0, 1, 3, 4, 2)  # (Bb,nc,g,hg,s)
    scores = cb * dec * dts[:, :, :, :, None, :]
    # scores: (Bb, nc, g, hg, l, s)
    xh = xc.reshape(bb, nc, q, g, hg, p)
    y_intra = jnp.einsum("bcghls,bcsghp->bclghp", scores, xh)

    # chunk states: S_c = sum_s exp(cum_last - cum_s) dt_s B_s ⊗ x_s
    last = cum[:, :, -1:, :]                                  # (Bb,nc,1,h)
    w_s = jnp.exp(last - cum) * dtc                           # (Bb,nc,q,h)
    wsh = w_s.reshape(bb, nc, q, g, hg)
    states = jnp.einsum("bcsgn,bcsgh,bcsghp->bcghnp", bc, wsh, xh)

    # inter-chunk recurrence over nc (sequential scan, nc is small)
    chunk_decay = jnp.exp(last[:, :, 0, :]).reshape(bb, nc, g, hg)  # (Bb,nc,g,hg)

    def body(carry, inp):
        s_c, dec_c = inp                            # (Bb,g,hg,n,p), (Bb,g,hg)
        new = carry * dec_c[..., None, None] + s_c
        return new, carry                           # emit state *before* chunk

    init = jnp.zeros((bb, g, hg, n, p), x.dtype)
    final_state, prev_states = jax.lax.scan(
        body, init, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
        unroll=unroll)
    prev_states = prev_states.swapaxes(0, 1)                  # (Bb,nc,g,hg,n,p)

    inner_decay = jnp.exp(cum).reshape(bb, nc, q, g, hg)
    y_inter = jnp.einsum("bclgn,bclgh,bcghnp->bclghp", cc, inner_decay, prev_states)

    y = (y_intra + y_inter).reshape(bb, l, h, p)
    return y + x * D[None, None, :, None], final_state.reshape(bb, h, n, p)


def apply_ssm(params: dict, x: jax.Array, cfg: ModelConfig, *,
              return_state: bool = False):
    """Full-sequence Mamba-2 block. x (B, L, d) -> (B, L, d) [, cache]."""
    dt_ = x.dtype
    zxbcdt = jnp.einsum("bld,dk->blk", x, params["in_proj"].astype(dt_))
    z, xbc_raw, dtraw = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc_raw, params["conv_w"].astype(dt_),
                       params["conv_b"].astype(dt_))
    di, g, n = cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state
    xs = xbc[..., :di]
    B = xbc[..., di : di + g * n].reshape(*xbc.shape[:2], g, n)
    C = xbc[..., di + g * n :].reshape(*xbc.shape[:2], g, n)
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    xh = xs.reshape(*xs.shape[:2], h, p)
    xh = logical_constraint(xh, "act_batch", "act_seq", "act_heads", None)
    dt = jax.nn.softplus(dtraw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, final_state = ssd_chunked(
        xh.astype(jnp.float32), dt, A,
        B.astype(jnp.float32), C.astype(jnp.float32), params["D"],
        chunk=cfg.ssm_chunk, unroll=cfg.unroll_scans)
    y = y.reshape(*xs.shape[:2], di).astype(dt_)
    # gated RMSNorm (mamba-2)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + cfg.norm_eps)
         * params["norm_scale"]).astype(dt_)
    out = jnp.einsum("bli,id->bld", y, params["out_proj"].astype(dt_))
    if return_state:
        k = cfg.ssm_conv
        conv_tail = xbc_raw[:, -(k - 1):, :] if xbc_raw.shape[1] >= k - 1 else jnp.pad(
            xbc_raw, ((0, 0), (k - 1 - xbc_raw.shape[1], 0), (0, 0)))
        cache = {"conv": conv_tail.astype(jnp.bfloat16),
                 "state": final_state.astype(jnp.float32)}
        return out, cache
    return out


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_ssm_cache(cfg: ModelConfig, batch: int, *, abstract: bool = False):
    h, n, p = cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_head_dim
    conv_shape = (batch, cfg.ssm_conv - 1, _conv_channels(cfg))
    state_shape = (batch, h, n, p)
    if abstract:
        return {"conv": jax.ShapeDtypeStruct(conv_shape, jnp.bfloat16),
                "state": jax.ShapeDtypeStruct(state_shape, jnp.float32)}
    return {"conv": jnp.zeros(conv_shape, jnp.bfloat16),
            "state": jnp.zeros(state_shape, jnp.float32)}


def ssm_cache_axes() -> dict:
    return {"conv": ("act_batch", None, "act_ssm_inner"),
            "state": ("act_batch", "act_heads", None, None)}


def apply_ssm_decode(params: dict, x: jax.Array, cfg: ModelConfig, cache: dict):
    """Single-token step. x (B, 1, d) -> (y (B, 1, d), new_cache)."""
    dt_ = x.dtype
    zxbcdt = jnp.einsum("bld,dk->blk", x, params["in_proj"].astype(dt_))
    z, xbc_new, dtraw = _split_proj(zxbcdt[:, 0, :], cfg)
    # conv over the rolling buffer
    conv_w = params["conv_w"].astype(dt_)
    hist = jnp.concatenate([cache["conv"].astype(dt_), xbc_new[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", hist, conv_w) + params["conv_b"].astype(dt_)
    xbc = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:, :].astype(cache["conv"].dtype)

    di, g, n = cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    xs = xbc[..., :di].reshape(-1, h, p).astype(jnp.float32)
    B = xbc[..., di : di + g * n].reshape(-1, g, n).astype(jnp.float32)
    C = xbc[..., di + g * n :].reshape(-1, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dtraw.astype(jnp.float32) + params["dt_bias"])  # (B,h)
    A = -jnp.exp(params["A_log"])
    da = jnp.exp(dt * A)                                       # (B,h)
    hg = h // g
    Bh = jnp.repeat(B, hg, axis=1)                             # (B,h,n)
    Ch = jnp.repeat(C, hg, axis=1)
    new_state = (cache["state"] * da[..., None, None]
                 + jnp.einsum("bhn,bh,bhp->bhnp", Bh, dt, xs))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state) + xs * params["D"][None, :, None]
    y = y.reshape(-1, di).astype(dt_) * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + cfg.norm_eps)
         * params["norm_scale"]).astype(dt_)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"].astype(dt_))
    return out[:, None, :], {"conv": new_conv, "state": new_state}
