"""Shared-memory interference: BwWrite co-runners vs NVDLA (paper sec 4.2).

BwWrite [Valsan et al., RTAS'16] writes sequentially through a working
set sized to land in a chosen level of the hierarchy.  Its effect on the
accelerator depends on where the WSS lands:

* **L1-fitting**  — cores never touch the shared fabric: no interference.
* **LLC-fitting** — co-runners occupy LLC bandwidth and evict the
  accelerator's freshly-filled blocks between its 32 B bursts:
  shared-bus queueing + an eviction probability that grows with the
  number of writers.
* **DRAM-fitting** — co-runners miss the LLC entirely: the accelerator
  loses DRAM bandwidth share and its row-buffer locality (FR-FCFS queue
  mixing raises effective latency).

Each co-runner case maps to a perturbed ``MemSystemConfig``; the
parameters below are calibrated once against Fig. 6's endpoints (2.1x /
2.5x at 4 co-runners) and produce the full curves in the benchmark.
"""
from __future__ import annotations

import dataclasses

from repro.core.accelerator import MemSystemConfig

# calibrated interference coefficients (see module docstring)
LLC_EVICT_PER_CORE = 0.15       # eviction probability added per writer
LLC_BUS_DELAY_PER_CORE = 24.0   # cycles of shared-bus queueing per writer
DRAM_LAT_PER_CORE = 0.09        # fractional DRAM latency growth per writer
DRAM_BW_PER_CORE = 0.14        # fraction of DRAM bandwidth taken per writer


def with_corunners(mem: MemSystemConfig, n: int, wss: str
                   ) -> MemSystemConfig:
    """Perturb the memory system for `n` BwWrite co-runners with working
    set `wss` in {"l1", "llc", "dram"}."""
    if n == 0 or wss == "l1":
        return mem
    if wss == "llc":
        return dataclasses.replace(
            mem,
            llc_eviction_prob=min(0.85, n * LLC_EVICT_PER_CORE),
            bus_delay_cycles=n * LLC_BUS_DELAY_PER_CORE,
        )
    if wss == "dram":
        # DRAM-fitting writers also sweep the LLC on their way out
        return dataclasses.replace(
            mem,
            llc_eviction_prob=min(0.9, n * LLC_EVICT_PER_CORE),
            bus_delay_cycles=n * LLC_BUS_DELAY_PER_CORE,
            extra_dram_latency=mem.t_dram_cycles * n * DRAM_LAT_PER_CORE,
            dram_bw_share=max(0.2, 1.0 - n * DRAM_BW_PER_CORE),
        )
    raise ValueError(f"unknown wss {wss!r}")
