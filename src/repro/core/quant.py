"""INT8 symmetric quantization for the accelerated path.

NVDLA computes conv/FC in int8 with per-channel weight scales (the
"calibration table" the NVDLA compiler produces); the CPU-side layers run
fp32 — the fp<->int conversions at the boundary are exactly the ones the
paper attributes to the processor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def calibrate(x: jax.Array, axis=None) -> jax.Array:
    """Symmetric amax calibration -> scale (per-`axis` or scalar)."""
    amax = jnp.max(jnp.abs(x)) if axis is None else \
        jnp.max(jnp.abs(x), axis=axis)
    return jnp.maximum(amax, 1e-12) / 127.0


def quantize(x: jax.Array, scale) -> jax.Array:
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def dequantize(q: jax.Array, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_conv_weights(w: jax.Array):
    """w (KH, KW, Cin, Cout) fp32 -> (int8, per-output-channel scale)."""
    scale = calibrate(w, axis=(0, 1, 2))            # (Cout,)
    return quantize(w, scale[None, None, None, :]), scale
