"""Set-associative LLC simulator — exact, vectorized, runtime-configurable.

The FireSim LLC model is runtime-configurable in sets/ways/block size
without an FPGA rebuild; this is the same knob set, as pure JAX.  State
is (tags, age) of shape (sets, ways); each access updates one set with
true LRU.  Three execution paths, all bit-identical in final state and
hit counts (tests/test_traces.py proves parity):

* **exact per-access scan** (``simulate_trace``): one ``lax.scan`` step
  per access — the reference semantics, used on unit-test traces and as
  the parity oracle;
* **compressed segment engine** (``simulate_segments``): a DBB stream is
  run-length-compressed into ``(base, stride, count)`` segments
  (``repro.core.traces``).  A sequential segment is analytically
  predictable under LRU, so it is retired either

  - in **O(1) serial steps** (closed form): when the segment sweeps every
    set at least ``ways`` times and none of its blocks are already
    resident, every first touch misses, victims cycle through the ways in
    prior-LRU order, and the final (tags, age) state and hit count are
    written directly with no scan at all; or
  - by the **per-set round scan**: one scan step retires one block *per
    set* (``sets`` blocks at once, each with all its intra-block burst
    repeats folded in), so serial depth drops from O(accesses) to
    O(blocks / sets) — exact for warm/overlapping/partial segments where
    the closed form does not apply.

  The exact per-access scan remains the fallback at segment boundaries
  that compression cannot express (stride > block size).
* **batched multi-geometry scan** (``repro.core.sweep``): (tags, age)
  padded to the largest geometry in a sweep and ``jax.vmap``-ed over
  (sets, ways, block_bytes) so a whole Fig. 5 grid compiles once and
  runs as a single device program.

Used two ways: exactly, on sampled windows of the NVDLA DBB stream (the
per-stream hit rates feed the accelerator timing model); and as the
reference that validates the closed-form stream-locality model in
``repro.core.accelerator`` (sequential-burst hit rate = 1 - 32/B).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LLCConfig:
    size_bytes: int = 2 * 1024 * 1024
    ways: int = 8
    block_bytes: int = 64

    @property
    def sets(self) -> int:
        return max(1, self.size_bytes // (self.ways * self.block_bytes))


def block_address(byte_addr, block_bytes: int):
    return byte_addr // block_bytes


def cold_state(sets: int, ways: int) -> tuple[jax.Array, jax.Array]:
    """The (tags, age) state of an empty cache."""
    return (jnp.full((sets, ways), -1, jnp.int32),
            jnp.zeros((sets, ways), jnp.int32))


@functools.partial(jax.jit, static_argnames=("sets", "ways"))
def _scan_trace(state, block_addrs, *, sets: int, ways: int):
    """Exact per-access scan from an arbitrary (tags, age) state."""
    set_idx = (block_addrs % sets).astype(jnp.int32)
    tag = (block_addrs // sets).astype(jnp.int32)

    def step(carry, inp):
        tags, age = carry                   # (sets, ways) each
        s, t = inp
        row_tags = tags[s]
        row_age = age[s]
        match = row_tags == t
        hit = jnp.any(match)
        way = jnp.where(hit, jnp.argmax(match), jnp.argmax(row_age))
        row_tags = row_tags.at[way].set(t)
        # true LRU: touched way -> age 0, everything else in the set +1
        row_age = jnp.where(jnp.arange(ways) == way, 0, row_age + 1)
        tags = tags.at[s].set(row_tags)
        age = age.at[s].set(row_age)
        return (tags, age), hit

    state, hits = jax.lax.scan(step, state, (set_idx, tag))
    return state, hits


def simulate_trace(block_addrs: jax.Array, *, sets: int, ways: int):
    """block_addrs (T,) int32 -> hits (T,) bool. True-LRU, allocate-on-miss
    (writes allocate too — NVDLA's DBB read/write bursts both fill)."""
    _, hits = _scan_trace(cold_state(sets, ways),
                          jnp.asarray(block_addrs), sets=sets, ways=ways)
    return hits


def hit_rate(block_addrs, cfg: LLCConfig) -> float:
    hits = simulate_trace(jnp.asarray(block_addrs, jnp.int32),
                          sets=cfg.sets, ways=cfg.ways)
    return float(jnp.mean(hits.astype(jnp.float32)))


def sequential_burst_trace(n_bursts: int, burst_bytes: int,
                           block_bytes: int, base: int = 0) -> jnp.ndarray:
    """Byte-sequential stream of `burst_bytes` bursts -> block addresses
    (the NVDLA weight/ifmap streaming pattern)."""
    byte_addrs = base + jnp.arange(n_bursts) * burst_bytes
    return block_address(byte_addrs, block_bytes).astype(jnp.int32)


# --------------------------------------------------------------------------
# compressed segment engine
# --------------------------------------------------------------------------
def _first_access(blocks, base, stride, block_bytes):
    """Index (within the segment) of the first access landing in each of
    `blocks` (accesses are base + j*stride, j in [0, count))."""
    lo = blocks * block_bytes - base
    return jnp.where(lo <= 0, 0, (lo + stride - 1) // stride)


def _last_access(blocks, base, stride, count, block_bytes):
    """Index of the last segment access landing in each of `blocks`."""
    lo = blocks * block_bytes - base
    return jnp.minimum(count - 1, (lo + block_bytes - 1) // stride)


def _block_counts(blocks, base, stride, count, block_bytes):
    """Exact number of segment accesses landing in each block of `blocks`
    (accesses are base + j*stride for j in [0, count))."""
    return (_last_access(blocks, base, stride, count, block_bytes)
            - _first_access(blocks, base, stride, block_bytes)
            + 1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("sets", "ways", "m_pad", "collect"))
def _segment_rounds_grouped(state, b_firsts, n_blockss, bases, strides,
                            counts, block_bytes,
                            *, sets: int, ways: int, m_pad: int,
                            collect: bool = False):
    """Per-set round scan over a *group* of segments (one device program
    per group, no per-segment dispatch).  Within a segment, round k
    retires, for every set at once, that set's k-th arriving block, with
    all its intra-block burst repeats folded into one LRU update
    (touched way -> age 0, other ways += accesses).  Sets are
    independent under LRU, so this is bit-identical to the per-access
    scan while cutting serial depth from O(count) to
    O(segments * n_blocks / sets).  Padding segments have count == 0 and
    update nothing.

    Returns per-segment hit counts; with ``collect`` it also returns the
    per-(segment, round, set) miss bits, from which the caller
    reconstructs the exact missed-block runs the DRAM model consumes."""
    s_idx = jnp.arange(sets)

    def per_segment(carry, meta):
        b_first, n_blocks, base, stride, count = meta
        off = (s_idx - b_first) % sets   # ordinal of a set's first block

        def round_k(inner, k):
            tags, age, hits = inner
            i = off + k * sets           # block ordinal within segment
            valid = i < n_blocks
            blocks = b_first + i
            t = (blocks // sets).astype(jnp.int32)
            a = _block_counts(blocks, base, stride, count, block_bytes)
            a = jnp.where(valid, a, 0)
            match = tags == t[:, None]
            hit = jnp.any(match, axis=1)
            way = jnp.where(hit, jnp.argmax(match, axis=1),
                            jnp.argmax(age, axis=1))
            touched = jnp.arange(ways)[None, :] == way[:, None]
            upd = valid[:, None]
            tags = jnp.where(upd & touched, t[:, None], tags)
            age = jnp.where(upd,
                            jnp.where(touched, 0, age + a[:, None]), age)
            hits = hits + jnp.sum(jnp.where(valid, a - 1 + hit, 0),
                                  dtype=jnp.int32)
            miss = (valid & ~hit) if collect else None
            return (tags, age, hits), miss

        tags, age = carry
        (tags, age, hits), miss = jax.lax.scan(
            round_k, (tags, age, jnp.int32(0)), jnp.arange(m_pad))
        return (tags, age), (hits, miss)

    state, (hits, miss) = jax.lax.scan(
        per_segment, state,
        (b_firsts, n_blockss, bases, strides, counts))
    return state, hits, miss


class _TouchedBlocks:
    """Host-side conservative residency tracker: the union of block
    intervals any earlier segment touched.  A segment disjoint from
    every touched interval provably has no resident blocks, so its
    disjointness can be decided without a device sync (the price of
    conservatism: a revisit of a long-evicted range still takes the
    round-scan path — exact either way)."""

    def __init__(self):
        self._iv: list[tuple[int, int]] = []   # merged, sorted

    def overlaps(self, lo: int, hi: int) -> bool:
        return any(a <= hi and lo <= b for a, b in self._iv)

    def add(self, lo: int, hi: int) -> None:
        merged = [(lo, hi)]
        for a, b in self._iv:
            if a <= merged[0][1] + 1 and merged[0][0] <= b + 1:
                merged[0] = (min(a, merged[0][0]), max(b, merged[0][1]))
            else:
                merged.append((a, b))
        self._iv = sorted(merged)


@functools.partial(jax.jit, static_argnames=("sets", "ways"))
def _segment_closed_form(state, b_first, n_blocks, a_interior, a_last,
                         *, sets: int, ways: int):
    """O(1)-serial state update for a full-sweep disjoint segment.

    Preconditions (checked by the caller): every set receives >= ways
    arrivals (n_blocks >= ways * sets), no segment block is resident
    beforehand, and interior block access counts are uniform (stride
    divides block size).  Then every first touch misses, so victims
    cycle through the ways in prior-LRU order: arrival j of a set lands
    on way rho[(j-1) % ways] where rho orders ways by descending prior
    age (stable — matching argmax's first-index tie-break).  The final
    occupants are each set's last `ways` arrivals and their ages are the
    access counts of the arrivals after them.
    """
    tags, age = state
    s_idx = jnp.arange(sets)
    off = (s_idx - b_first) % sets
    m_s = (n_blocks - off + sets - 1) // sets        # arrivals per set
    rho = jnp.argsort(-age, axis=1, stable=True)     # (S, W) victim order
    q = jnp.arange(ways)[None, :]
    jstar = m_s[:, None] - ((m_s[:, None] - 1 - q) % ways)   # 1-indexed
    i_star = off[:, None] + (jstar - 1) * sets
    new_tag = ((b_first + i_star) // sets).astype(jnp.int32)
    # age of the way holding arrival j* = accesses of arrivals after it;
    # all interior blocks count a_interior, except the segment's very
    # last block (partial) — in its set's suffix unless it *is* j*.
    s_last = (b_first + n_blocks - 1) % sets
    in_suffix_last = (s_idx[:, None] == s_last) & (jstar < m_s[:, None])
    new_age = ((m_s[:, None] - jstar) * a_interior
               + jnp.where(in_suffix_last, a_last - a_interior, 0)
               ).astype(jnp.int32)
    # scatter rank-ordered results back to way positions
    tags = jnp.zeros_like(tags).at[s_idx[:, None], rho].set(new_tag)
    age = jnp.zeros_like(age).at[s_idx[:, None], rho].set(new_age)
    return (tags, age)


# --------------------------------------------------------------------------
# segment-lane engine: geometry as *traced* operands
# --------------------------------------------------------------------------
def segment_lane_scan(bases, strides, counts, r_needed, cold,
                      sets, ways, block_bytes, way_sels=None,
                      *, max_sets: int, max_ways: int, r_pad: int,
                      collect: bool = False, suffix: str = "full",
                      return_state: bool = False):
    """One sweep lane's exact segment replay with *runtime* geometry.

    ``bases/strides/counts`` are (S,) int32 segment streams (count == 0
    entries are padding and update nothing); ``sets/ways/block_bytes``
    are traced scalars bounded by the static ``max_sets``/``max_ways``
    paddings, so ``jax.vmap`` over lanes turns a whole geometry grid
    into one compiled program (``repro.core.sweep.segment_lane_hit_counts``).
    ``r_needed``/``cold`` are host-side execution plans: the number of
    round-scan rounds this segment needs (an upper bound across the
    vmapped lanes — extra rounds are masked no-ops, missing rounds would
    be wrong) and whether the segment's byte range is provably disjoint
    from everything replayed before it.

    Per segment the update is the same exact decomposition the
    single-geometry engine uses, expressed uniformly so every lane runs
    the same program:

    * a per-set round scan retires the first min(n_blocks, ways*sets)
      blocks (one block per set per round, all intra-block burst repeats
      folded into one LRU touch) in ``r_needed`` dynamic rounds — zero
      for a ``cold`` segment, whose arrivals provably all miss;
    * the rest of the segment finishes with a closed-form suffix: after
      `ways` arrivals in every set the cache provably holds exactly
      those arrivals — whatever was resident before — so every suffix
      block misses and victims cycle through the ways oldest-first (for
      a ``cold`` segment the "suffix" is the whole segment, with any
      per-set arrival count).  The final occupants and their last-touch
      timestamps are written directly.

    LRU is tracked as a global last-touch timestamp (recency order, and
    so every victim choice including first-index tie-breaks, is
    identical to the per-set age counters of the reference simulator).
    State is laid out (ways, sets) — way-reductions run over the small
    leading axis with sets contiguous, which is what XLA:CPU vectorizes
    well.  Requires stride <= block_bytes for every (segment, lane)
    pair — the caller checks; DBB traces are 32 B-stride so every
    standard geometry qualifies.  Returns per-segment hit counts (S,)
    int32; hit counts are bit-identical to expanding the trace and
    running the exact per-access scan at that geometry.

    ``collect=True`` (static) additionally returns the round-scan miss
    bits, (S, r_pad, max_sets) bool: entry (j, k, s) is True iff round k
    of segment j missed in set s.  Together with the analytically-known
    suffix (every block past the round-scanned prefix misses), the
    caller can reconstruct each segment's exact missed-block runs — the
    compressed currency of the DRAM row model — without per-access
    expansion (``repro.core.sweep.interference_lane_metrics_batch``).

    ``suffix`` (static) specializes the closed-form suffix from the
    host plan:

    * ``"full"`` — the general oldest-first rank insert, any suffix
      depth;
    * ``"one"`` — every (segment, lane) suffix leaves at most one block
      per set (n_blocks - n_pre <= sets): the insert is a plain
      oldest-way eviction, O(ways) per set instead of the O(ways^2)
      rank computation, which otherwise dominates the whole scan;
    * ``"none"`` — every segment retires entirely in the round scan
      (no cold segments and n_blocks <= ways*sets everywhere, so
      n_suf == 0): the suffix block is dropped from the program.

    ``way_sels`` (optional, (S,) int32) adds LLC **way-masking
    partitioning** (Intel CAT semantics, FireSim's LLC model knob): a
    per-segment bitmask of the ways the segment's master may *allocate*
    into on a miss.  Hits are unrestricted — a line is served from
    whichever way holds it, and the touch updates that way's recency —
    only victim selection is confined to the mask, so disjoint masks
    give each master a private partition of every set.  A zero mask
    means "unpartitioned" (the full-mask behavior, bit-exactly — the
    sentinel lets one vmapped batch mix masked and unmasked lanes).
    Masked segments (mask != 0) retire entirely in the round scan —
    the closed-form suffix assumes unrestricted LRU victim cycling —
    so the caller's plan must give them ``ceil(n_blocks / sets)``
    rounds and their ``cold`` flag is ignored.  Callers guarantee
    ``mask & ((1 << ways) - 1) != 0`` (an empty partition cannot
    allocate anywhere).

    ``return_state`` (static) additionally returns the final
    ``(tags, ts)`` state, (max_ways, max_sets) each — the partition
    invariant tests decode it to prove masked ways never hold the
    victim's lines.
    """
    s_idx = jnp.arange(max_sets, dtype=jnp.int32)
    q_idx = jnp.arange(max_ways, dtype=jnp.int32)
    set_mask = s_idx < sets
    way_mask = q_idx < ways
    imax = jnp.iinfo(jnp.int32).max
    bb = block_bytes

    masked = way_sels is not None

    def per_segment(carry, meta):
        tags, ts, counter = carry          # (max_ways, max_sets) x2, scalar
        if masked:
            base, stride, count, rounds, is_cold, wsel = meta
            # allocation mask: mask bits limited to real ways; the zero
            # sentinel means unpartitioned (alloc anywhere real)
            alloc = way_mask & ((wsel == 0) | (((wsel >> q_idx) & 1) != 0))
        else:
            base, stride, count, rounds, is_cold = meta
            wsel = jnp.int32(0)
            alloc = way_mask
        live = count > 0
        b_first = base // bb
        b_last = (base + (count - 1) * stride) // bb
        n_blocks = jnp.where(live, b_last - b_first + 1, 0)
        full = ways * sets
        n_pre = jnp.where(is_cold, 0, jnp.minimum(n_blocks, full))
        if masked:
            # a partitioned segment cannot use the suffix closed form
            # (victims cycle within its mask, not all ways): the whole
            # segment goes through the round scan
            n_pre = jnp.where(wsel != 0, n_blocks, n_pre)
        off = jnp.where(set_mask, (s_idx - b_first) % sets, 0)

        def round_k(k, inner):
            tags, ts, hits, miss_buf = inner
            i = off + jnp.int32(k) * sets  # block ordinal within segment
            v = set_mask & (i < n_pre) & live
            blocks = b_first + i
            t = (blocks // sets).astype(jnp.int32)
            j_lo = _first_access(blocks, base, stride, bb)
            j_hi = _last_access(blocks, base, stride, count, bb)
            a = (j_hi - j_lo + 1).astype(jnp.int32)
            # one fused reduction picks the touched way: a matching tag
            # wins outright (key -1, unique per set), else the oldest
            # real way (padded ways pinned to int32 max; the cumsum
            # first-min mask reproduces argmin's first-index tie-break
            # without a gather — XLA:CPU gathers cost ~100ns/element,
            # elementwise ops ~1ns)
            key = jnp.where(tags == t[None, :], -1,
                            jnp.where(alloc[:, None], ts, imax))
            kmin = jnp.min(key, axis=0)
            hit = kmin == -1
            is_min = key == kmin[None, :]
            first_min = (jnp.cumsum(is_min, axis=0) == 1) & is_min
            touched = first_min & v[None, :]
            tags = jnp.where(touched, t[None, :], tags)
            ts = jnp.where(touched,
                           (counter + j_hi[None, :] + 1).astype(jnp.int32),
                           ts)
            hits = hits + jnp.sum(jnp.where(v, a - 1 + hit, 0),
                                  dtype=jnp.int32)
            if collect:
                miss_buf = miss_buf.at[k].set(v & ~hit)
            return (tags, ts, hits, miss_buf)

        miss_init = jnp.zeros((r_pad, max_sets) if collect else (0, 0),
                              jnp.bool_)
        tags, ts, hits, miss_buf = jax.lax.fori_loop(
            0, jnp.minimum(rounds, r_pad), round_k,
            (tags, ts, jnp.int32(0), miss_init))

        if suffix == "none":
            counter = counter + jnp.where(live, count, 0)
            return (tags, ts, counter), (hits, miss_buf)

        # closed-form suffix: everything past the round-scanned prefix
        # (the whole segment when cold)
        sb_first = b_first + n_pre
        n_suf = jnp.maximum(n_blocks - n_pre, 0)
        has_suf = n_suf > 0
        off_suf = jnp.where(set_mask, (s_idx - sb_first) % sets, 0)
        victim_ts = jnp.where(way_mask[:, None], ts, imax)
        if suffix == "one":
            # at most one suffix block per set: it evicts the oldest
            # way (min ts, first-index tie-break via the same cumsum
            # first-min mask as the round scan)
            ins = set_mask & live & (off_suf < n_suf)
            vmin = jnp.min(victim_ts, axis=0)
            is_old = victim_ts == vmin[None, :]
            oldest = (jnp.cumsum(is_old, axis=0) == 1) & is_old
            blk1 = sb_first + off_suf
            t1 = (blk1 // sets).astype(jnp.int32)
            ts1 = counter + _last_access(blk1, base, stride, count, bb) + 1
            wr = oldest & ins[None, :]
            tags = jnp.where(wr, t1[None, :], tags)
            ts = jnp.where(wr, ts1[None, :].astype(jnp.int32), ts)
        else:
            m_s = jnp.where(off_suf < n_suf,
                            (n_suf - off_suf + sets - 1) // sets, 0)
            # each way's rank in oldest-first recency order (stable:
            # ties break on way index) via an O(ways^2) comparison
            # count — the scatter/argsort formulation this replaces
            # dominated the whole scan on CPU (batched scatters
            # serialize per element)
            older = ((victim_ts[None, :, :] < victim_ts[:, None, :])
                     | ((victim_ts[None, :, :] == victim_ts[:, None, :])
                        & (q_idx[None, :, None] < q_idx[:, None, None])))
            rank = jnp.sum(older, axis=1).astype(jnp.int32)
            jstar = m_s[None, :] - ((m_s[None, :] - 1 - rank) % ways)
            valid_q = (way_mask[:, None] & (jstar >= 1)
                       & set_mask[None, :] & live)
            blk = sb_first + off_suf[None, :] + (jstar - 1) * sets
            t_star = (blk // sets).astype(jnp.int32)
            ts_star = (counter
                       + _last_access(blk, base, stride, count, bb) + 1)
            tags = jnp.where(valid_q, t_star, tags)
            ts = jnp.where(valid_q, ts_star.astype(jnp.int32), ts)
        # every suffix access beyond a block's first touch hits
        j_split = jnp.where(has_suf,
                            _first_access(sb_first, base, stride, bb),
                            count)
        hits = hits + jnp.where(has_suf, (count - j_split) - n_suf, 0)
        counter = counter + jnp.where(live, count, 0)
        return (tags, ts, counter), (hits, miss_buf)

    init = (jnp.full((max_ways, max_sets), -1, jnp.int32),
            jnp.zeros((max_ways, max_sets), jnp.int32),
            jnp.int32(0))
    xs = [bases, strides, counts, r_needed,
          jnp.asarray(cold).astype(jnp.bool_)]
    if masked:
        xs.append(jnp.asarray(way_sels).astype(jnp.int32))
    (tags_f, ts_f, _), (per_seg_hits, miss_bits) = jax.lax.scan(
        per_segment, init, tuple(xs))
    out = (per_seg_hits,)
    if collect:
        out += (miss_bits,)
    if return_state:
        out += ((tags_f, ts_f),)
    return out if len(out) > 1 else out[0]


@dataclasses.dataclass
class SegmentSimResult:
    hits: int
    accesses: int
    state: tuple                 # final (tags, age)
    closed_form_segments: int    # retired with the O(1) analytic update
    round_scanned_segments: int  # retired with the per-set round scan
    expanded_segments: int       # fell back to the exact per-access scan
    per_segment_hits: np.ndarray | None = None   # (n_segments,) int64
    miss_runs: list | None = None  # [(first_block, n_blocks, seg_idx)]

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.accesses)


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


def _append_block_runs(runs: list, blocks: np.ndarray, idx: int) -> None:
    """Compress a sorted array of distinct block indices into maximal
    consecutive (first_block, n_blocks, segment_idx) runs."""
    if blocks.size == 0:
        return
    cut = np.nonzero(np.diff(blocks) != 1)[0]
    starts = np.concatenate([[0], cut + 1])
    ends = np.concatenate([cut, [blocks.size - 1]])
    for a, b in zip(starts, ends):
        runs.append((int(blocks[a]), int(b - a + 1), idx))


def simulate_segments(segments, cfg: LLCConfig, state=None, *,
                      per_segment: bool = False,
                      collect_miss_runs: bool = False) -> SegmentSimResult:
    """Replay a compressed DBB trace (iterable of objects/tuples with
    ``base, stride, count`` in bytes/bursts, stride > 0) through the
    LLC, optionally continuing from a prior (tags, age) ``state``.

    Dispatches each segment to the cheapest exact path: closed form when
    it fully sweeps a provably non-resident region, per-set round scan
    otherwise, exact per-access scan only when compression cannot
    express the segment (stride > block size).  Consecutive round-scan
    segments with the same round budget are fused into one device
    program, and hit counters stay on device until the end, so the hot
    loop performs no per-segment synchronization.  Hit counts and final
    state are bit-identical to expanding the segments and running
    ``simulate_trace`` on the concatenation.

    ``per_segment`` additionally attributes hits to each input segment
    (``result.per_segment_hits``, aligned with the input order — the
    sim-driven accelerator model sums these by stream).
    ``collect_miss_runs`` reconstructs the exact LLC-miss stream as
    maximal runs of consecutive missed blocks in access order
    (``result.miss_runs``) — the compressed currency of the closed-form
    DRAM row model in ``repro.core.dram.segment_row_hits``.
    """
    sets, ways, bb = cfg.sets, cfg.ways, cfg.block_bytes
    collect = collect_miss_runs
    touched = _TouchedBlocks()
    if state is None:
        state = cold_state(sets, ways)
    else:
        # arbitrary warm state: anything may be resident, so no segment
        # is provably disjoint (long ones still split fast — the
        # prefix/suffix proof is dynamic and needs no tracker)
        touched.add(-(1 << 62), 1 << 62)
    accesses = 0
    n_cf = n_rs = n_ex = 0
    n_input = 0
    # replay log, resolved to host values once at the end (device arrays
    # are only synced after the whole trace is dispatched):
    #   ("group", idxs, metas, hits_dev, miss_dev)
    #   ("cf",    idx, first_block, n_blocks, hits_int)
    #   ("ex",    idx, hit_bits_dev, blocks_dev)
    order_log: list[tuple] = []
    pending: list[tuple] = []  # (idx, (b_first, n_blocks, base, stride, cnt))
    pending_m = 0

    def flush():
        nonlocal state, pending, pending_m
        if not pending:
            return
        idxs = [i for i, _ in pending]
        metas = [m for _, m in pending]
        k_pad = _next_pow2(len(metas))
        metas_p = metas + [(0, 0, 0, 1, 0)] * (k_pad - len(metas))
        cols = list(np.asarray(metas_p, np.int32).T)
        state, h, miss = _segment_rounds_grouped(
            state, *cols, bb, sets=sets, ways=ways, m_pad=pending_m,
            collect=collect)
        order_log.append(("group", idxs, metas, h, miss))
        pending, pending_m = [], 0

    from repro.core.traces import segment_tuple

    for idx, seg in enumerate(segments):
        n_input = idx + 1
        base, stride, count = segment_tuple(seg)
        if count <= 0:
            continue
        if stride <= 0:
            raise ValueError(
                f"segment stride must be positive, got {stride} "
                "(a repeated single address is not a compressible "
                "sequential burst stream)")
        accesses += count
        if stride > bb:
            # blocks are non-contiguous: expand and scan exactly
            flush()
            addrs = (base + jnp.arange(count) * stride) // bb
            blocks_dev = addrs.astype(jnp.int32)
            state, h = _scan_trace(state, blocks_dev,
                                   sets=sets, ways=ways)
            order_log.append(("ex", idx, h, blocks_dev))
            touched.add(base // bb, (base + (count - 1) * stride) // bb)
            n_ex += 1
            continue
        b_first = base // bb
        b_last = (base + (count - 1) * stride) // bb
        n_blocks = b_last - b_first + 1
        uniform = bb % stride == 0
        disjoint = not touched.overlaps(b_first, b_last)
        if uniform and not disjoint and n_blocks >= 2 * (ways + 1) * sets:
            # long warm segment: once every set has seen >= ways arrivals
            # the cache holds exactly those arrivals (LRU always evicts a
            # pre-segment resident before any arrival), so everything
            # past a (ways+1)*sets-block prefix is provably non-resident
            # no matter what was cached before.  Round-scan the prefix,
            # closed-form the suffix.
            split_block = b_first + (ways + 1) * sets
            j_split = -(-(split_block * bb - base) // stride)
            m = _next_pow2(ways + 1)
            if pending and m != pending_m:
                flush()
            pending.append((idx, (b_first, split_block - b_first, base,
                                  stride, j_split)))
            pending_m = m
            flush()
            n_rs += 1
            suf_base = base + j_split * stride
            suf_count = count - j_split
            n_blocks_suf = b_last - split_block + 1
            lo = b_last * bb - suf_base
            a_last = suf_count - (0 if lo <= 0 else -(-lo // stride))
            state = _segment_closed_form(
                state, split_block, n_blocks_suf, bb // stride, a_last,
                sets=sets, ways=ways)
            order_log.append(("cf", idx, split_block, n_blocks_suf,
                              suf_count - n_blocks_suf))
            n_cf += 1
            touched.add(b_first, b_last)
            continue
        if n_blocks >= ways * sets and uniform and disjoint:
            flush()
            a_int = bb // stride
            lo = b_last * bb - base
            j_lo = 0 if lo <= 0 else -(-lo // stride)
            a_last = count - j_lo
            state = _segment_closed_form(
                state, b_first, n_blocks, a_int, a_last,
                sets=sets, ways=ways)
            order_log.append(("cf", idx, b_first, n_blocks,
                              count - n_blocks))
            n_cf += 1
        else:
            m = _next_pow2(-(-n_blocks // sets))
            if pending and m != pending_m:
                flush()
            pending.append((idx, (b_first, n_blocks, base, stride, count)))
            pending_m = m
            n_rs += 1
        touched.add(b_first, b_last)
    flush()

    # resolve the log: total hits, optional per-segment attribution and
    # miss-run reconstruction — device arrays sync here, once
    hits = 0
    per_seg = np.zeros(n_input, np.int64) if per_segment else None
    miss_runs: list | None = [] if collect else None
    for entry in order_log:
        if entry[0] == "group":
            _, idxs, metas, h_dev, miss_dev = entry
            h = np.asarray(h_dev)
            hits += int(h[:len(idxs)].sum())
            if per_seg is not None:
                for j, i in enumerate(idxs):
                    per_seg[i] += int(h[j])
            if collect:
                mb = np.asarray(miss_dev)        # (k_pad, m_pad, sets)
                for j, (b_first, n_blocks, _b, _s, _c) in enumerate(metas):
                    k_idx, s_np = np.nonzero(mb[j])
                    if k_idx.size == 0:
                        continue
                    off = (s_np - b_first) % sets
                    blocks = b_first + np.sort(off + k_idx * sets)
                    _append_block_runs(miss_runs, blocks, idxs[j])
        elif entry[0] == "cf":
            _, i, first_block, n_blocks, h_int = entry
            hits += h_int
            if per_seg is not None:
                per_seg[i] += h_int
            if collect:
                miss_runs.append((first_block, n_blocks, i))
        else:                                    # "ex"
            _, i, h_dev, blocks_dev = entry
            h = np.asarray(h_dev)
            hits += int(h.sum())
            if per_seg is not None:
                per_seg[i] += int(h.sum())
            if collect:
                _append_block_runs(miss_runs,
                                   np.asarray(blocks_dev)[~h], i)
    return SegmentSimResult(hits=hits, accesses=accesses, state=state,
                            closed_form_segments=n_cf,
                            round_scanned_segments=n_rs,
                            expanded_segments=n_ex,
                            per_segment_hits=per_seg,
                            miss_runs=miss_runs)


def hit_rate_segments(segments, cfg: LLCConfig) -> float:
    """LLC hit rate of a compressed trace (exact, never expands unless a
    segment's stride exceeds the block size)."""
    return simulate_segments(segments, cfg).hit_rate
