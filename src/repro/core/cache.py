"""Set-associative LLC simulator — exact, vectorized, runtime-configurable.

The FireSim LLC model is runtime-configurable in sets/ways/block size
without an FPGA rebuild; this is the same knob set, as a pure-JAX
``lax.scan`` over an access trace (so it jit-compiles once per geometry
and is differentiably composable with the rest of the stack if needed).

State is (tags, age) of shape (sets, ways); each access updates one set
with true LRU.  Used two ways:
* exactly, on unit-test traces and on sampled windows of the NVDLA DBB
  stream (the per-stream hit rates feed the accelerator timing model);
* as the reference that validates the closed-form stream-locality model
  in ``repro.core.accelerator`` (sequential-burst hit rate = 1 - 32/B).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LLCConfig:
    size_bytes: int = 2 * 1024 * 1024
    ways: int = 8
    block_bytes: int = 64

    @property
    def sets(self) -> int:
        return max(1, self.size_bytes // (self.ways * self.block_bytes))


def block_address(byte_addr, block_bytes: int):
    return byte_addr // block_bytes


@functools.partial(jax.jit, static_argnames=("sets", "ways"))
def simulate_trace(block_addrs: jax.Array, *, sets: int, ways: int):
    """block_addrs (T,) int32 -> hits (T,) bool. True-LRU, allocate-on-miss
    (writes allocate too — NVDLA's DBB read/write bursts both fill)."""
    set_idx = block_addrs % sets
    tag = block_addrs // sets

    def step(carry, inp):
        tags, age = carry                   # (sets, ways) each
        s, t = inp
        row_tags = tags[s]
        row_age = age[s]
        match = row_tags == t
        hit = jnp.any(match)
        way = jnp.where(hit, jnp.argmax(match), jnp.argmax(row_age))
        row_tags = row_tags.at[way].set(t)
        # true LRU: touched way -> age 0, everything else in the set +1
        row_age = jnp.where(jnp.arange(ways) == way, 0, row_age + 1)
        tags = tags.at[s].set(row_tags)
        age = age.at[s].set(row_age)
        return (tags, age), hit

    init = (jnp.full((sets, ways), -1, jnp.int32),
            jnp.zeros((sets, ways), jnp.int32))
    _, hits = jax.lax.scan(step, init, (set_idx, tag))
    return hits


def hit_rate(block_addrs, cfg: LLCConfig) -> float:
    hits = simulate_trace(jnp.asarray(block_addrs, jnp.int32),
                          sets=cfg.sets, ways=cfg.ways)
    return float(jnp.mean(hits.astype(jnp.float32)))


def sequential_burst_trace(n_bursts: int, burst_bytes: int,
                           block_bytes: int, base: int = 0) -> jnp.ndarray:
    """Byte-sequential stream of `burst_bytes` bursts -> block addresses
    (the NVDLA weight/ifmap streaming pattern)."""
    byte_addrs = base + jnp.arange(n_bursts) * burst_bytes
    return block_address(byte_addrs, block_bytes).astype(jnp.int32)
