"""Set-associative LLC simulator — exact, vectorized, runtime-configurable.

The FireSim LLC model is runtime-configurable in sets/ways/block size
without an FPGA rebuild; this is the same knob set, as pure JAX.  State
is (tags, age) of shape (sets, ways); each access updates one set with
true LRU.  Three execution paths, all bit-identical in final state and
hit counts (tests/test_traces.py proves parity):

* **exact per-access scan** (``simulate_trace``): one ``lax.scan`` step
  per access — the reference semantics, used on unit-test traces and as
  the parity oracle;
* **compressed segment engine** (``simulate_segments``): a DBB stream is
  run-length-compressed into ``(base, stride, count)`` segments
  (``repro.core.traces``).  A sequential segment is analytically
  predictable under LRU, so it is retired either

  - in **O(1) serial steps** (closed form): when the segment sweeps every
    set at least ``ways`` times and none of its blocks are already
    resident, every first touch misses, victims cycle through the ways in
    prior-LRU order, and the final (tags, age) state and hit count are
    written directly with no scan at all; or
  - by the **per-set round scan**: one scan step retires one block *per
    set* (``sets`` blocks at once, each with all its intra-block burst
    repeats folded in), so serial depth drops from O(accesses) to
    O(blocks / sets) — exact for warm/overlapping/partial segments where
    the closed form does not apply.

  The exact per-access scan remains the fallback at segment boundaries
  that compression cannot express (stride > block size).
* **batched multi-geometry scan** (``repro.core.sweep``): (tags, age)
  padded to the largest geometry in a sweep and ``jax.vmap``-ed over
  (sets, ways, block_bytes) so a whole Fig. 5 grid compiles once and
  runs as a single device program.

Used two ways: exactly, on sampled windows of the NVDLA DBB stream (the
per-stream hit rates feed the accelerator timing model); and as the
reference that validates the closed-form stream-locality model in
``repro.core.accelerator`` (sequential-burst hit rate = 1 - 32/B).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LLCConfig:
    size_bytes: int = 2 * 1024 * 1024
    ways: int = 8
    block_bytes: int = 64

    @property
    def sets(self) -> int:
        return max(1, self.size_bytes // (self.ways * self.block_bytes))


def block_address(byte_addr, block_bytes: int):
    return byte_addr // block_bytes


def cold_state(sets: int, ways: int) -> tuple[jax.Array, jax.Array]:
    """The (tags, age) state of an empty cache."""
    return (jnp.full((sets, ways), -1, jnp.int32),
            jnp.zeros((sets, ways), jnp.int32))


@functools.partial(jax.jit, static_argnames=("sets", "ways"))
def _scan_trace(state, block_addrs, *, sets: int, ways: int):
    """Exact per-access scan from an arbitrary (tags, age) state."""
    set_idx = (block_addrs % sets).astype(jnp.int32)
    tag = (block_addrs // sets).astype(jnp.int32)

    def step(carry, inp):
        tags, age = carry                   # (sets, ways) each
        s, t = inp
        row_tags = tags[s]
        row_age = age[s]
        match = row_tags == t
        hit = jnp.any(match)
        way = jnp.where(hit, jnp.argmax(match), jnp.argmax(row_age))
        row_tags = row_tags.at[way].set(t)
        # true LRU: touched way -> age 0, everything else in the set +1
        row_age = jnp.where(jnp.arange(ways) == way, 0, row_age + 1)
        tags = tags.at[s].set(row_tags)
        age = age.at[s].set(row_age)
        return (tags, age), hit

    state, hits = jax.lax.scan(step, state, (set_idx, tag))
    return state, hits


def simulate_trace(block_addrs: jax.Array, *, sets: int, ways: int):
    """block_addrs (T,) int32 -> hits (T,) bool. True-LRU, allocate-on-miss
    (writes allocate too — NVDLA's DBB read/write bursts both fill)."""
    _, hits = _scan_trace(cold_state(sets, ways),
                          jnp.asarray(block_addrs), sets=sets, ways=ways)
    return hits


def hit_rate(block_addrs, cfg: LLCConfig) -> float:
    hits = simulate_trace(jnp.asarray(block_addrs, jnp.int32),
                          sets=cfg.sets, ways=cfg.ways)
    return float(jnp.mean(hits.astype(jnp.float32)))


def sequential_burst_trace(n_bursts: int, burst_bytes: int,
                           block_bytes: int, base: int = 0) -> jnp.ndarray:
    """Byte-sequential stream of `burst_bytes` bursts -> block addresses
    (the NVDLA weight/ifmap streaming pattern)."""
    byte_addrs = base + jnp.arange(n_bursts) * burst_bytes
    return block_address(byte_addrs, block_bytes).astype(jnp.int32)


# --------------------------------------------------------------------------
# compressed segment engine
# --------------------------------------------------------------------------
def _block_counts(blocks, base, stride, count, block_bytes):
    """Exact number of segment accesses landing in each block of `blocks`
    (accesses are base + j*stride for j in [0, count))."""
    lo = blocks * block_bytes - base
    j_lo = jnp.maximum(0, (lo + stride - 1) // stride)
    j_lo = jnp.where(lo <= 0, 0, j_lo)
    j_hi = jnp.minimum(count - 1,
                       (lo + block_bytes - 1) // stride)
    return (j_hi - j_lo + 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("sets", "ways", "m_pad"))
def _segment_rounds_grouped(state, b_firsts, n_blockss, bases, strides,
                            counts, block_bytes,
                            *, sets: int, ways: int, m_pad: int):
    """Per-set round scan over a *group* of segments (one device program
    per group, no per-segment dispatch).  Within a segment, round k
    retires, for every set at once, that set's k-th arriving block, with
    all its intra-block burst repeats folded into one LRU update
    (touched way -> age 0, other ways += accesses).  Sets are
    independent under LRU, so this is bit-identical to the per-access
    scan while cutting serial depth from O(count) to
    O(segments * n_blocks / sets).  Padding segments have count == 0 and
    update nothing."""
    s_idx = jnp.arange(sets)

    def per_segment(carry, meta):
        b_first, n_blocks, base, stride, count = meta
        off = (s_idx - b_first) % sets   # ordinal of a set's first block

        def round_k(inner, k):
            tags, age, hits = inner
            i = off + k * sets           # block ordinal within segment
            valid = i < n_blocks
            blocks = b_first + i
            t = (blocks // sets).astype(jnp.int32)
            a = _block_counts(blocks, base, stride, count, block_bytes)
            a = jnp.where(valid, a, 0)
            match = tags == t[:, None]
            hit = jnp.any(match, axis=1)
            way = jnp.where(hit, jnp.argmax(match, axis=1),
                            jnp.argmax(age, axis=1))
            touched = jnp.arange(ways)[None, :] == way[:, None]
            upd = valid[:, None]
            tags = jnp.where(upd & touched, t[:, None], tags)
            age = jnp.where(upd,
                            jnp.where(touched, 0, age + a[:, None]), age)
            hits = hits + jnp.sum(jnp.where(valid, a - 1 + hit, 0))
            return (tags, age, hits), None

        tags, age = carry
        (tags, age, hits), _ = jax.lax.scan(
            round_k, (tags, age, jnp.int32(0)), jnp.arange(m_pad))
        return (tags, age), hits

    state, hits = jax.lax.scan(
        per_segment, state,
        (b_firsts, n_blockss, bases, strides, counts))
    return state, jnp.sum(hits)


class _TouchedBlocks:
    """Host-side conservative residency tracker: the union of block
    intervals any earlier segment touched.  A segment disjoint from
    every touched interval provably has no resident blocks, so its
    disjointness can be decided without a device sync (the price of
    conservatism: a revisit of a long-evicted range still takes the
    round-scan path — exact either way)."""

    def __init__(self):
        self._iv: list[tuple[int, int]] = []   # merged, sorted

    def overlaps(self, lo: int, hi: int) -> bool:
        return any(a <= hi and lo <= b for a, b in self._iv)

    def add(self, lo: int, hi: int) -> None:
        merged = [(lo, hi)]
        for a, b in self._iv:
            if a <= merged[0][1] + 1 and merged[0][0] <= b + 1:
                merged[0] = (min(a, merged[0][0]), max(b, merged[0][1]))
            else:
                merged.append((a, b))
        self._iv = sorted(merged)


@functools.partial(jax.jit, static_argnames=("sets", "ways"))
def _segment_closed_form(state, b_first, n_blocks, a_interior, a_last,
                         *, sets: int, ways: int):
    """O(1)-serial state update for a full-sweep disjoint segment.

    Preconditions (checked by the caller): every set receives >= ways
    arrivals (n_blocks >= ways * sets), no segment block is resident
    beforehand, and interior block access counts are uniform (stride
    divides block size).  Then every first touch misses, so victims
    cycle through the ways in prior-LRU order: arrival j of a set lands
    on way rho[(j-1) % ways] where rho orders ways by descending prior
    age (stable — matching argmax's first-index tie-break).  The final
    occupants are each set's last `ways` arrivals and their ages are the
    access counts of the arrivals after them.
    """
    tags, age = state
    s_idx = jnp.arange(sets)
    off = (s_idx - b_first) % sets
    m_s = (n_blocks - off + sets - 1) // sets        # arrivals per set
    rho = jnp.argsort(-age, axis=1, stable=True)     # (S, W) victim order
    q = jnp.arange(ways)[None, :]
    jstar = m_s[:, None] - ((m_s[:, None] - 1 - q) % ways)   # 1-indexed
    i_star = off[:, None] + (jstar - 1) * sets
    new_tag = ((b_first + i_star) // sets).astype(jnp.int32)
    # age of the way holding arrival j* = accesses of arrivals after it;
    # all interior blocks count a_interior, except the segment's very
    # last block (partial) — in its set's suffix unless it *is* j*.
    s_last = (b_first + n_blocks - 1) % sets
    in_suffix_last = (s_idx[:, None] == s_last) & (jstar < m_s[:, None])
    new_age = ((m_s[:, None] - jstar) * a_interior
               + jnp.where(in_suffix_last, a_last - a_interior, 0)
               ).astype(jnp.int32)
    # scatter rank-ordered results back to way positions
    tags = jnp.zeros_like(tags).at[s_idx[:, None], rho].set(new_tag)
    age = jnp.zeros_like(age).at[s_idx[:, None], rho].set(new_age)
    return (tags, age)


@dataclasses.dataclass
class SegmentSimResult:
    hits: int
    accesses: int
    state: tuple                 # final (tags, age)
    closed_form_segments: int    # retired with the O(1) analytic update
    round_scanned_segments: int  # retired with the per-set round scan
    expanded_segments: int       # fell back to the exact per-access scan

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.accesses)


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


def simulate_segments(segments, cfg: LLCConfig, state=None
                      ) -> SegmentSimResult:
    """Replay a compressed DBB trace (iterable of objects/tuples with
    ``base, stride, count`` in bytes/bursts, stride > 0) through the
    LLC, optionally continuing from a prior (tags, age) ``state``.

    Dispatches each segment to the cheapest exact path: closed form when
    it fully sweeps a provably non-resident region, per-set round scan
    otherwise, exact per-access scan only when compression cannot
    express the segment (stride > block size).  Consecutive round-scan
    segments with the same round budget are fused into one device
    program, and hit counters stay on device until the end, so the hot
    loop performs no per-segment synchronization.  Hit counts and final
    state are bit-identical to expanding the segments and running
    ``simulate_trace`` on the concatenation.
    """
    sets, ways, bb = cfg.sets, cfg.ways, cfg.block_bytes
    touched = _TouchedBlocks()
    if state is None:
        state = cold_state(sets, ways)
    else:
        # arbitrary warm state: anything may be resident, so no segment
        # is provably disjoint (long ones still split fast — the
        # prefix/suffix proof is dynamic and needs no tracker)
        touched.add(-(1 << 62), 1 << 62)
    accesses = 0
    n_cf = n_rs = n_ex = 0
    hit_parts: list = []       # device scalars; summed once at the end
    closed_form_hits = 0
    # plan: classify every segment on the host, then execute, fusing
    # consecutive round-scan segments that share an m_pad bucket
    pending: list[tuple] = []  # (b_first, n_blocks, base, stride, count)
    pending_m = 0

    def flush():
        nonlocal state, pending, pending_m
        if not pending:
            return
        k_pad = _next_pow2(len(pending))
        pad = k_pad - len(pending)
        metas = pending + [(0, 0, 0, 1, 0)] * pad
        cols = list(np.asarray(metas, np.int32).T)
        state, h = _segment_rounds_grouped(
            state, *cols, bb, sets=sets, ways=ways, m_pad=pending_m)
        hit_parts.append(h)
        pending, pending_m = [], 0

    for seg in segments:
        base, stride, count = (seg if isinstance(seg, tuple)
                               else (seg.base, seg.stride, seg.count))
        if count <= 0:
            continue
        if stride <= 0:
            raise ValueError(
                f"segment stride must be positive, got {stride} "
                "(a repeated single address is not a compressible "
                "sequential burst stream)")
        accesses += count
        if stride > bb:
            # blocks are non-contiguous: expand and scan exactly
            flush()
            addrs = (base + jnp.arange(count) * stride) // bb
            state, h = _scan_trace(state, addrs.astype(jnp.int32),
                                   sets=sets, ways=ways)
            hit_parts.append(jnp.sum(h, dtype=jnp.int32))
            touched.add(base // bb, (base + (count - 1) * stride) // bb)
            n_ex += 1
            continue
        b_first = base // bb
        b_last = (base + (count - 1) * stride) // bb
        n_blocks = b_last - b_first + 1
        uniform = bb % stride == 0
        disjoint = not touched.overlaps(b_first, b_last)
        if uniform and not disjoint and n_blocks >= 2 * (ways + 1) * sets:
            # long warm segment: once every set has seen >= ways arrivals
            # the cache holds exactly those arrivals (LRU always evicts a
            # pre-segment resident before any arrival), so everything
            # past a (ways+1)*sets-block prefix is provably non-resident
            # no matter what was cached before.  Round-scan the prefix,
            # closed-form the suffix.
            split_block = b_first + (ways + 1) * sets
            j_split = -(-(split_block * bb - base) // stride)
            m = _next_pow2(ways + 1)
            if pending and m != pending_m:
                flush()
            pending.append((b_first, split_block - b_first, base, stride,
                            j_split))
            pending_m = m
            flush()
            n_rs += 1
            suf_base = base + j_split * stride
            suf_count = count - j_split
            n_blocks_suf = b_last - split_block + 1
            lo = b_last * bb - suf_base
            a_last = suf_count - (0 if lo <= 0 else -(-lo // stride))
            state = _segment_closed_form(
                state, split_block, n_blocks_suf, bb // stride, a_last,
                sets=sets, ways=ways)
            closed_form_hits += suf_count - n_blocks_suf
            n_cf += 1
            touched.add(b_first, b_last)
            continue
        if n_blocks >= ways * sets and uniform and disjoint:
            flush()
            a_int = bb // stride
            lo = b_last * bb - base
            j_lo = 0 if lo <= 0 else -(-lo // stride)
            a_last = count - j_lo
            state = _segment_closed_form(
                state, b_first, n_blocks, a_int, a_last,
                sets=sets, ways=ways)
            closed_form_hits += count - n_blocks
            n_cf += 1
        else:
            m = _next_pow2(-(-n_blocks // sets))
            if pending and m != pending_m:
                flush()
            pending.append((b_first, n_blocks, base, stride, count))
            pending_m = m
            n_rs += 1
        touched.add(b_first, b_last)
    flush()
    hits = closed_form_hits + int(sum(int(h) for h in hit_parts))
    return SegmentSimResult(hits=hits, accesses=accesses, state=state,
                            closed_form_segments=n_cf,
                            round_scanned_segments=n_rs,
                            expanded_segments=n_ex)


def hit_rate_segments(segments, cfg: LLCConfig) -> float:
    """LLC hit rate of a compressed trace (exact, never expands unless a
    segment's stride exceeds the block size)."""
    return simulate_segments(segments, cfg).hit_rate
