"""Run-length-compressed NVDLA DBB traces.

A full YOLOv3 frame is ~60M DBB bursts; materializing it as a per-access
array (let alone scanning it serially) is unusable.  But the DBB traffic
is *structured*: every AccelOp reads its weights, streams its ifmap and
writes its ofmap as byte-sequential 32 B bursts from a handful of base
addresses.  This module expresses that stream exactly as ``Segment``
records — ``(base, stride, count)`` arithmetic progressions of byte
addresses — generated straight from the command stream that
``repro.core.runtime`` compiles out of ``yolov3.LAYERS``:

* weights live in a packed read-only region, re-streamed once per tile
  pass (``weight_passes`` segments over the same bytes — real temporal
  reuse the LLC can catch);
* feature maps ping-pong between two activation regions (the producer's
  ofmap region is the consumer's ifmap region);
* the DBB arbiter interleaves the three streams; ``interleave`` models
  that by splitting segments into round-robin chunks at a configurable
  burst granularity (the compressed simulator falls back from the
  closed form to its per-set scan exactly at these interleave points).

``repro.core.cache.simulate_segments`` consumes these directly;
``expand`` materializes the identical per-access byte trace for parity
testing and for the vmapped window sweeps in ``repro.core.sweep``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.runtime import AccelOp, CommandStream, compile_network

BURST_BYTES = 32       # NVDLA DBB minimum burst (paper sec. 4.1)

# Physical DBB address width: NVDLA's DBB interface and the SoC DRAM
# map are comfortably inside 40 bits (1 TiB).  Segment constructors
# reject anything past it — an address that "works" only because numpy
# int64 happens to hold it is a generator bug, not a bigger DRAM.
DRAM_ADDR_BITS = 40

# DBB address map: weights packed from 0, activations ping-pong in two
# regions well above the weight heap (YOLOv3 needs ~62 MiB of weights
# and < 16 MiB per feature map).  The regions are staggered by distinct
# DRAM-row offsets (row = 2 KiB, 32 banks -> 64 KiB bank-rotation
# period): concurrent sequential streams advance through banks in
# lockstep, and with bank-aligned bases they would all ride the *same*
# bank forever, each interleave point closing the others' open row — an
# address-map pathology real allocators don't produce.
WEIGHT_REGION = 0x0000_0000            # bank offset  0
FMAP_REGION_A = 0x1000_0000 + 11 * 2048   # bank offset 11
FMAP_REGION_B = 0x1800_0000 + 22 * 2048   # bank offset 22


@dataclasses.dataclass(frozen=True)
class Segment:
    """`count` bursts at `base`, `base+stride`, ... (byte addresses)."""
    base: int
    stride: int
    count: int
    stream: str = ""           # "weight" | "ifmap" | "ofmap" (labelling)

    def __post_init__(self):
        if self.count < 0:
            raise ValueError(
                f"segment count must be >= 0, got {self.count} — a "
                "negative burst count has no trace meaning; clip the "
                "generator's arithmetic (traces.window drops empties)")
        if self.stride < 0:
            raise ValueError(
                f"segment stride must be >= 0, got {self.stride} — "
                "descending streams are not representable; emit the "
                "ascending run and reorder at the consumer")
        if self.count > 0:
            if self.stride == 0:
                raise ValueError(
                    "segment stride must be positive for a non-empty "
                    "segment — a repeated single address is not a "
                    "compressible sequential burst stream")
            if self.base < 0:
                raise ValueError(
                    f"segment base must be >= 0, got {self.base:#x} — "
                    "byte addresses are physical DBB addresses")
            last = self.base + (self.count - 1) * self.stride
            if last >= 1 << DRAM_ADDR_BITS:
                raise ValueError(
                    f"segment end address {last:#x} exceeds the "
                    f"{DRAM_ADDR_BITS}-bit DRAM address space "
                    f"({1 << DRAM_ADDR_BITS:#x}) — rebase the trace or "
                    "shrink count/stride; see traces.DRAM_ADDR_BITS")

    @property
    def bytes(self) -> int:
        return self.count * self.stride

    def split(self, chunk_bursts: int) -> list["Segment"]:
        """Cut into chunks of at most `chunk_bursts` bursts.  A zero- (or
        negative-) count segment yields no chunks — never a zero-count
        chunk that would expand to an empty array."""
        out = []
        done = 0
        while done < self.count:
            n = min(chunk_bursts, self.count - done)
            out.append(Segment(self.base + done * self.stride,
                               self.stride, n, self.stream))
            done += n
        return out


def segment_tuple(seg) -> tuple[int, int, int]:
    """Normalize a ``Segment`` or raw ``(base, stride, count)`` tuple —
    the one definition of the segment protocol every compressed-trace
    consumer (LLC engine, DRAM row model, sweep lanes) unpacks through."""
    return (seg if isinstance(seg, tuple)
            else (seg.base, seg.stride, seg.count))


def _bursts(n_bytes: int) -> int:
    return -(-n_bytes // BURST_BYTES)


def op_segments(op: AccelOp, weight_base: int, ifmap_base: int,
                ofmap_base: int) -> list[Segment]:
    """One AccelOp's DBB streams as segments, in issue order: each tile
    pass re-streams the weights, then the ifmap share, then the ofmap
    share (matching the traffic accounting in ``repro.core.runtime``)."""
    segs: list[Segment] = []
    passes = max(1, op.weight_passes)
    w_per_pass = op.weight_traffic // passes
    i_total, o_total = op.ifmap_traffic, op.ofmap_traffic
    i_done = o_done = 0
    for p in range(passes):
        if w_per_pass:
            segs.append(Segment(weight_base, BURST_BYTES,
                                _bursts(w_per_pass), "weight"))
        i_share = i_total * (p + 1) // passes - i_done
        o_share = o_total * (p + 1) // passes - o_done
        if i_share:
            segs.append(Segment(ifmap_base + i_done, BURST_BYTES,
                                _bursts(i_share), "ifmap"))
        if o_share:
            segs.append(Segment(ofmap_base + o_done, BURST_BYTES,
                                _bursts(o_share), "ofmap"))
        i_done += i_share
        o_done += o_share
    return segs


def network_op_segments(stream: CommandStream | None = None,
                        max_ops: int | None = None) -> list[list[Segment]]:
    """Per-AccelOp DBB streams over the shared address map — the same
    segments ``network_trace`` emits, kept grouped by op so per-layer
    consumers (the sim-driven ``repro.core.accelerator`` hit rates) can
    attribute hits to the op that issued them.

    Weight regions are packed in layer order; feature maps ping-pong
    between two regions so a consumer reads where its producer wrote.
    """
    stream = stream or compile_network()
    ops = stream.accel_ops[:max_ops] if max_ops else stream.accel_ops
    per_op: list[list[Segment]] = []
    w_cursor = WEIGHT_REGION
    regions = (FMAP_REGION_A, FMAP_REGION_B)
    for i, op in enumerate(ops):
        ifmap_base = regions[i % 2]
        ofmap_base = regions[(i + 1) % 2]
        per_op.append(op_segments(op, w_cursor, ifmap_base, ofmap_base))
        passes = max(1, op.weight_passes)
        w_cursor += op.weight_traffic // passes
    return per_op


def network_trace(stream: CommandStream | None = None,
                  max_ops: int | None = None) -> list[Segment]:
    """The whole accelerated network's DBB stream, compressed (the
    flattened ``network_op_segments``)."""
    return [seg for op_segs in network_op_segments(stream, max_ops)
            for seg in op_segs]


def interleave(segments: list[Segment], chunk_bursts: int = 64
               ) -> list[Segment]:
    """Round-robin the streams at `chunk_bursts` granularity — the DBB
    arbiter's view.  Segments with distinct `stream` labels alternate;
    order within a stream is preserved.  The result is still a valid
    compressed trace (many short segments)."""
    lanes: dict[str, list[Segment]] = {}
    for seg in segments:
        lanes.setdefault(seg.stream or "_", []).extend(
            seg.split(chunk_bursts))
    out: list[Segment] = []
    queues = list(lanes.values())
    idx = [0] * len(queues)
    while True:
        progressed = False
        for q, queue in enumerate(queues):
            if idx[q] < len(queue):
                out.append(queue[idx[q]])
                idx[q] += 1
                progressed = True
        if not progressed:
            return out


def window(segments: list[Segment], max_bursts: int) -> list[Segment]:
    """Clip a compressed trace to its first `max_bursts` accesses.

    Zero-count segments (an input clipped at an exact chunk boundary, or
    an already-empty segment) are dropped rather than kept as count-0
    records: downstream consumers concatenate ``expand``-ed pieces and a
    degenerate segment would contribute an empty array with nothing to
    pin its dtype or base address."""
    out: list[Segment] = []
    left = max_bursts
    for seg in segments:
        if left <= 0:
            break
        n = min(seg.count, left)
        if n > 0:
            out.append(dataclasses.replace(seg, count=n))
            left -= n
    return out


def total_bursts(segments: list[Segment]) -> int:
    return sum(s.count for s in segments)


def expand(segments: list[Segment]) -> np.ndarray:
    """Materialize the exact per-access byte-address trace (int64 numpy;
    parity-test oracle — never needed on the fast path)."""
    parts = [s.base + np.arange(s.count, dtype=np.int64) * s.stride
             for s in segments if s.count > 0]
    if not parts:
        return np.zeros((0,), np.int64)
    return np.concatenate(parts)


def default_dbb_window(max_bursts: int = 4096, chunk_bursts: int = 16,
                       layer_index: int = 40) -> list[Segment]:
    """A representative DBB window for sweeps: a mid-network conv layer's
    weight/ifmap/ofmap streams, arbiter-interleaved."""
    stream = compile_network()
    ops = stream.accel_ops
    op = ops[min(layer_index, len(ops) - 1)]
    segs = op_segments(op, WEIGHT_REGION, FMAP_REGION_A, FMAP_REGION_B)
    return window(interleave(segs, chunk_bursts), max_bursts)
