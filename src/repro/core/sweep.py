"""Batched memory-system sweeps: one compiled program per grid.

The seed path ran every sweep point through its own ``lax.scan`` —
and because ``simulate_trace`` specializes on (sets, ways), every
geometry was a fresh XLA compile.  Here the (tags, age) state is padded
to the largest geometry in the sweep and the exact LLC scan is
``jax.vmap``-ed over per-lane (sets, ways, block_bytes) scalars, so the
entire Fig. 5 LLC grid (and the Fig. 6 interference grid, which vmaps
over per-lane *traces*) compiles once and runs as a single device
program.  Padded ways are masked out of both tag match and victim
selection, so each lane is bit-identical to the unbatched simulator
(tests/test_sweep.py).

Public API:
* ``batched_hit_rates``   — (configs,) hit rates of one byte trace;
* ``batched_hits``        — the raw per-access hit bits per lane;
* ``sweep_llc``           — Fig. 5 grid: closed-form speedups + vmapped
                            simulated hit rates on a real DBB window;
* ``sweep_interference``  — Fig. 6 grid: closed-form slowdowns + vmapped
                            simulated hit rates under BwWrite co-runners.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import LLCConfig
from repro.core import traces
from repro.utils.env import as_address_array


@functools.partial(jax.jit, static_argnames=("max_sets", "max_ways"))
def _simulate_padded(byte_addrs, sets, ways, block_bytes,
                     *, max_sets: int, max_ways: int):
    """Exact LLC scan with *runtime* geometry on padded state.

    sets/ways/block_bytes are traced scalars <= the static paddings.
    LRU is tracked as a last-touch timestamp instead of the reference
    simulator's per-set age counters: the recency *order* (and so every
    victim choice, including the first-index tie-break among untouched
    ways) is identical, but the state update touches one scalar per
    access instead of a whole way row.  Ways >= `ways` never match
    (masked) and never win victim selection (timestamp pinned to
    int32 max), so hits are bit-identical to the unpadded simulator for
    the same geometry."""
    block = byte_addrs // block_bytes
    set_idx = (block % sets).astype(jnp.int32)
    tag = (block // sets).astype(jnp.int32)
    way_mask = jnp.arange(max_ways) < ways
    imax = jnp.iinfo(jnp.int32).max

    def step(carry, inp):
        tags, ts = carry                     # (max_sets, max_ways)
        s, t, k = inp
        row_tags = tags[s]
        row_ts = ts[s]
        match = (row_tags == t) & way_mask
        hit = jnp.any(match)
        victim_ts = jnp.where(way_mask, row_ts, imax)
        way = jnp.where(hit, jnp.argmax(match), jnp.argmin(victim_ts))
        tags = tags.at[s, way].set(t)
        ts = ts.at[s, way].set(k)
        return (tags, ts), hit

    init = (jnp.full((max_sets, max_ways), -1, jnp.int32),
            jnp.zeros((max_sets, max_ways), jnp.int32))
    stamps = jnp.arange(1, byte_addrs.shape[0] + 1, dtype=jnp.int32)
    _, hits = jax.lax.scan(step, init, (set_idx, tag, stamps))
    return hits


def _geometry_arrays(configs):
    sets = jnp.asarray([c.sets for c in configs], jnp.int32)
    ways = jnp.asarray([c.ways for c in configs], jnp.int32)
    blocks = jnp.asarray([c.block_bytes for c in configs], jnp.int32)
    max_sets = max(c.sets for c in configs)
    max_ways = max(c.ways for c in configs)
    return sets, ways, blocks, max_sets, max_ways


def batched_hits(byte_addrs, configs: list[LLCConfig]) -> jax.Array:
    """(n_cfg, T) per-access hit bits — every lane bit-identical to the
    unbatched ``simulate_trace`` at that geometry, one compile total."""
    sets, ways, blocks, max_sets, max_ways = _geometry_arrays(configs)
    addrs = as_address_array(byte_addrs, what="DBB trace")
    sim = jax.vmap(
        functools.partial(_simulate_padded,
                          max_sets=max_sets, max_ways=max_ways),
        in_axes=(None, 0, 0, 0))
    return sim(addrs, sets, ways, blocks)


def batched_hit_rates(byte_addrs, configs: list[LLCConfig]) -> jax.Array:
    return jnp.mean(batched_hits(byte_addrs, configs).astype(jnp.float32),
                    axis=1)


def segment_sweep_hit_rates(segments, configs: list[LLCConfig]
                            ) -> np.ndarray:
    """(n_cfg,) exact hit rates of one *compressed* trace — each config
    replayed through the segment engine (closed form / per-set rounds),
    so whole-network windows are feasible where per-access expansion is
    not.  Exactly ``hit_rate`` of the expanded trace, per config."""
    from repro.core.cache import simulate_segments

    return np.asarray([simulate_segments(segments, c).hit_rate
                       for c in configs], np.float64)


def batched_hits_per_trace(byte_addrs_2d, configs: list[LLCConfig]
                           ) -> jax.Array:
    """Like ``batched_hits`` but with one trace per lane (n_cfg, T) —
    used by the interference sweep where co-runners change the trace."""
    sets, ways, blocks, max_sets, max_ways = _geometry_arrays(configs)
    sim = jax.vmap(
        functools.partial(_simulate_padded,
                          max_sets=max_sets, max_ways=max_ways),
        in_axes=(0, 0, 0, 0))
    return sim(as_address_array(byte_addrs_2d, what="DBB trace"),
               sets, ways, blocks)


# --------------------------------------------------------------------------
# Fig. 5 — LLC geometry sweep
# --------------------------------------------------------------------------
def grid_configs(sizes_kib, blocks) -> dict[tuple, LLCConfig]:
    """The Fig. 5 grid's (size, block) -> LLCConfig mapping — delegates
    to ``repro.core.soc.llc_config_for`` so the simulated and
    closed-form sweeps always describe the same geometry."""
    from repro.core.soc import llc_config_for

    return {(size, block): llc_config_for(size, block)
            for block in blocks for size in sizes_kib}


def sweep_llc(sizes_kib=(0.5, 2, 8, 64, 512, 1024, 4096),
              blocks=(32, 64, 128), soc=None,
              window_bursts: int = 4096) -> dict:
    """Fig. 5, batched: the closed-form timing grid (`grid`, `no_llc_s`)
    plus exact simulated hit rates for every geometry (`sim_hit_rates`)
    from a single vmapped program over a real interleaved DBB window."""
    from repro.core.soc import SoCConfig, llc_sweep as _closed_form

    soc = soc or SoCConfig()
    out = _closed_form(sizes_kib=sizes_kib, blocks=blocks, soc=soc)
    cfgs = grid_configs(sizes_kib, blocks)
    win = traces.default_dbb_window(max_bursts=window_bursts)
    addrs = traces.expand(win)
    rates = batched_hit_rates(addrs, list(cfgs.values()))
    out["sim_hit_rates"] = {key: float(r)
                            for key, r in zip(cfgs, np.asarray(rates))}
    out["window_bursts"] = traces.total_bursts(win)
    return out


@functools.partial(jax.jit, static_argnames=("banks",))
def _dram_row_hits(byte_addrs, miss, *, banks: int, row_bytes: int):
    """Row-hit bit per access, where only LLC misses (`miss`) touch the
    open-row state — the DRAM side of the pipeline, vmappable."""
    row = byte_addrs // row_bytes
    bank = (row % banks).astype(jnp.int32)
    row_of_bank = (row // banks).astype(jnp.int32)

    def step(open_rows, inp):
        b, r, m = inp
        hit = (open_rows[b] == r) & m
        open_rows = jnp.where(m, open_rows.at[b].set(r), open_rows)
        return open_rows, hit

    init = jnp.full((banks,), -1, jnp.int32)
    _, hits = jax.lax.scan(step, init, (bank, row_of_bank, miss))
    return hits


# --------------------------------------------------------------------------
# Fig. 6 — interference sweep
# --------------------------------------------------------------------------
def _corunner_trace(llc: LLCConfig, n: int, wss: str, t_total: int,
                    nvdla_addrs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """One lane's interleaved trace: 1 NVDLA burst then one write from
    each of `n` BwWrite co-runners, repeated to `t_total` accesses.
    Returns (byte_addrs, nvdla_mask).  Co-runner working sets: "llc"
    wraps inside half the LLC (occupies it), "dram" streams far past it
    (sweeps it), "l1" never reaches the shared fabric (no accesses)."""
    if wss == "l1":
        n = 0
    period = 1 + n
    slots = np.arange(t_total)
    lane = slots % period
    nvdla_mask = lane == 0
    addrs = np.zeros(t_total, np.int64)
    n_nv = int(nvdla_mask.sum())
    addrs[nvdla_mask] = nvdla_addrs[np.arange(n_nv) % len(nvdla_addrs)]
    for w in range(1, period):
        m = lane == w
        k = int(m.sum())
        step = np.arange(k, dtype=np.int64) * 64          # 64 B lines
        if wss == "llc":
            span = max(64, llc.size_bytes // 2)
            region = 0x4000_0000 + (w - 1) * 0x0100_0000
            addrs[m] = region + (step % span)
        else:                                             # "dram"
            span = llc.size_bytes * 8
            region = 0x6000_0000 + (w - 1) * 0x0800_0000
            addrs[m] = region + (step % span)
    return addrs, nvdla_mask


def sweep_interference(soc=None, corunners=(0, 1, 2, 3, 4),
                       window_bursts: int = 4096) -> dict:
    """Fig. 6, batched: closed-form slowdown curves (`l1`/`llc`/`dram`)
    plus, per (wss, n), the *simulated* NVDLA hit rate with co-runner
    write streams physically interleaved into the trace (`sim_hit_rates`)
    — all lanes one vmapped program."""
    from repro.core.dram import DRAMConfig
    from repro.core.soc import SoCConfig, interference_sweep as _closed_form

    soc = soc or SoCConfig()
    out = _closed_form(soc=soc, corunners=corunners)
    llc = soc.mem.llc or LLCConfig()
    dram = soc.mem.dram or DRAMConfig()
    nvdla = traces.expand(traces.default_dbb_window(
        max_bursts=window_bursts))
    # l1-fitting co-runners never reach the shared fabric, so every
    # ('l1', n) lane is the solo-NVDLA trace — simulate it once and fan
    # the result out to all n below
    lanes, traces_2d, masks, cfgs = [], [], [], []
    for wss, ns in (("l1", (0,)), ("llc", corunners), ("dram", corunners)):
        for n in ns:
            a, m = _corunner_trace(llc, n, wss, window_bursts, nvdla)
            lanes.append((wss, n))
            traces_2d.append(a)
            masks.append(m)
            cfgs.append(llc)
    stacked = np.stack(traces_2d)
    hits = np.asarray(batched_hits_per_trace(stacked, cfgs))
    # DRAM behind the LLC: misses of *all* masters mix in the banks, so
    # co-runner misses break the NVDLA stream's row locality — the
    # FR-FCFS disruption Fig. 6 attributes the "dram" slowdown to.
    row_hits = np.asarray(jax.vmap(
        functools.partial(_dram_row_hits, banks=dram.banks,
                          row_bytes=dram.row_bytes))(
        as_address_array(stacked, what="DBB trace"), jnp.asarray(~hits)))
    out["sim_hit_rates"] = {}
    out["sim_row_hit_rates"] = {}
    for i, (wss, n) in enumerate(lanes):
        nv = masks[i]
        hr = float(hits[i][nv].mean())
        nv_miss = nv & ~hits[i]
        rh = float(row_hits[i][nv_miss].mean()) if nv_miss.any() else 1.0
        for key in ([(wss, n)] if wss != "l1"
                    else [("l1", m) for m in corunners]):
            out["sim_hit_rates"][key] = hr
            out["sim_row_hit_rates"][key] = rh
    return out
