"""Batched memory-system sweeps: one compiled program per grid.

The seed path ran every sweep point through its own ``lax.scan`` —
and because ``simulate_trace`` specializes on (sets, ways), every
geometry was a fresh XLA compile.  Two batched engines fix that, both
padding state to the largest geometry and ``jax.vmap``-ing the exact
LLC update over per-lane (sets, ways, block_bytes) scalars so a whole
grid compiles once and runs as a single device program:

* the **per-access engine** (``batched_hits``/``batched_hits_per_trace``)
  scans an expanded byte trace — per-access hit *bits*, serial depth
  O(accesses);
* the **segment-lane engine** (``segment_lane_hit_counts``/``_rates``)
  replays the *compressed* trace of ``repro.core.traces`` directly —
  the geometry-traced segment kernel of ``repro.core.cache`` retires a
  whole (base, stride, count) run per step, so serial depth is
  O(segments * max_ways) and full-frame multi-config sweeps (the trace
  lengths Fig. 5/6 actually need) fit in one program.

Padded ways are masked out of both tag match and victim selection, so
each lane is bit-identical to the unbatched simulator at that geometry
(tests/test_sweep.py).

Public API:
* ``batched_hit_rates``        — (configs,) hit rates of one byte trace;
* ``batched_hits``             — the raw per-access hit bits per lane;
* ``segment_lane_hit_counts``  — (configs, segments) compressed-trace
                                 hit counts, shared or per-lane traces;
* ``segment_lane_hit_rates``   — the per-lane rates thereof;
* ``sweep_llc``           — Fig. 5 grid: closed-form speedups + exact
                            segment-lane hit rates, windowed or full
                            frame;
* ``sweep_interference``  — Fig. 6 grid: closed-form slowdowns + exact
                            segment-lane hit rates and closed-form DRAM
                            row-hit rates under BwWrite co-runners.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import traces
from repro.core.cache import LLCConfig
from repro.utils.env import as_address_array


@functools.partial(jax.jit, static_argnames=("max_sets", "max_ways"))
def _simulate_padded(byte_addrs, sets, ways, block_bytes,
                     *, max_sets: int, max_ways: int):
    """Exact LLC scan with *runtime* geometry on padded state.

    sets/ways/block_bytes are traced scalars <= the static paddings.
    LRU is tracked as a last-touch timestamp instead of the reference
    simulator's per-set age counters: the recency *order* (and so every
    victim choice, including the first-index tie-break among untouched
    ways) is identical, but the state update touches one scalar per
    access instead of a whole way row.  Ways >= `ways` never match
    (masked) and never win victim selection (timestamp pinned to
    int32 max), so hits are bit-identical to the unpadded simulator for
    the same geometry."""
    block = byte_addrs // block_bytes
    set_idx = (block % sets).astype(jnp.int32)
    tag = (block // sets).astype(jnp.int32)
    way_mask = jnp.arange(max_ways) < ways
    imax = jnp.iinfo(jnp.int32).max

    def step(carry, inp):
        tags, ts = carry                     # (max_sets, max_ways)
        s, t, k = inp
        row_tags = tags[s]
        row_ts = ts[s]
        match = (row_tags == t) & way_mask
        hit = jnp.any(match)
        victim_ts = jnp.where(way_mask, row_ts, imax)
        way = jnp.where(hit, jnp.argmax(match), jnp.argmin(victim_ts))
        tags = tags.at[s, way].set(t)
        ts = ts.at[s, way].set(k)
        return (tags, ts), hit

    init = (jnp.full((max_sets, max_ways), -1, jnp.int32),
            jnp.zeros((max_sets, max_ways), jnp.int32))
    stamps = jnp.arange(1, byte_addrs.shape[0] + 1, dtype=jnp.int32)
    _, hits = jax.lax.scan(step, init, (set_idx, tag, stamps))
    return hits


def _geometry_arrays(configs):
    sets = jnp.asarray([c.sets for c in configs], jnp.int32)
    ways = jnp.asarray([c.ways for c in configs], jnp.int32)
    blocks = jnp.asarray([c.block_bytes for c in configs], jnp.int32)
    max_sets = max(c.sets for c in configs)
    max_ways = max(c.ways for c in configs)
    return sets, ways, blocks, max_sets, max_ways


def batched_hits(byte_addrs, configs: list[LLCConfig]) -> jax.Array:
    """(n_cfg, T) per-access hit bits — every lane bit-identical to the
    unbatched ``simulate_trace`` at that geometry, one compile total."""
    sets, ways, blocks, max_sets, max_ways = _geometry_arrays(configs)
    addrs = as_address_array(byte_addrs, what="DBB trace")
    sim = jax.vmap(
        functools.partial(_simulate_padded,
                          max_sets=max_sets, max_ways=max_ways),
        in_axes=(None, 0, 0, 0))
    return sim(addrs, sets, ways, blocks)


def batched_hit_rates(byte_addrs, configs: list[LLCConfig]) -> jax.Array:
    return jnp.mean(batched_hits(byte_addrs, configs).astype(jnp.float32),
                    axis=1)


def segment_sweep_hit_rates(segments, configs: list[LLCConfig]
                            ) -> np.ndarray:
    """(n_cfg,) exact hit rates of one *compressed* trace — each config
    replayed through the segment engine (closed form / per-set rounds),
    so whole-network windows are feasible where per-access expansion is
    not.  Exactly ``hit_rate`` of the expanded trace, per config."""
    from repro.core.cache import simulate_segments

    return np.asarray([simulate_segments(segments, c).hit_rate
                       for c in configs], np.float64)


# --------------------------------------------------------------------------
# segment-lane engine: vmapped segment replay over runtime geometry
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=32)
def _lane_engine(max_sets: int, max_ways: int, r_pad: int,
                 per_lane_trace: bool):
    from repro.core.cache import segment_lane_scan

    in_axes = ((0, 0, 0, 0, 0, 0, 0, 0) if per_lane_trace
               else (None, None, None, None, None, 0, 0, 0))
    return jax.jit(jax.vmap(
        functools.partial(segment_lane_scan, max_sets=max_sets,
                          max_ways=max_ways, r_pad=r_pad),
        in_axes=in_axes))


def _lane_plan(trace: list, configs: list[LLCConfig]
               ) -> tuple[np.ndarray, np.ndarray]:
    """Host-side execution plan for one segment stream over a lane
    bucket: per segment, the round-scan rounds needed (max across the
    bucket's geometries — extra rounds in other lanes are masked no-ops)
    and whether the segment is provably cold (byte range disjoint, with
    block-alignment slack, from every earlier segment — all its arrivals
    miss in every lane, so the closed form needs no rounds at all)."""
    from repro.core.cache import _TouchedBlocks

    metas = [_segment_tuple(s) for s in trace]
    base = np.asarray([m[0] for m in metas], np.int64)
    stride = np.asarray([m[1] for m in metas], np.int64)
    count = np.asarray([m[2] for m in metas], np.int64)
    live = count > 0
    last = base + np.maximum(count - 1, 0) * stride
    slack = max(c.block_bytes for c in configs) - 1
    touched = _TouchedBlocks()
    cold = np.zeros(len(metas), bool)
    for j in range(len(metas)):
        if not live[j]:
            continue
        lo, hi = int(base[j] - slack), int(last[j] + slack)
        cold[j] = not touched.overlaps(lo, hi)
        touched.add(lo, hi)
    r = np.zeros(len(metas), np.int64)
    for c in configs:
        nb = last // c.block_bytes - base // c.block_bytes + 1
        r = np.maximum(r, np.minimum(c.ways, -(-nb // c.sets)))
    r = np.where(live & ~cold, r, 0)
    return r.astype(np.int32), cold


_segment_tuple = traces.segment_tuple


def _lane_meta_arrays(lanes: list[list]) -> tuple:
    """Per-lane segment streams -> (n_lane, max_segments) int32 metadata
    arrays, padded with count == 0 no-op segments."""
    n_seg = max((len(t) for t in lanes), default=0)
    shape = (len(lanes), max(1, n_seg))
    bases = np.zeros(shape, np.int32)
    strides = np.ones(shape, np.int32)
    counts = np.zeros(shape, np.int32)
    for i, trace in enumerate(lanes):
        for j, seg in enumerate(trace):
            bases[i, j], strides[i, j], counts[i, j] = _segment_tuple(seg)
    return jnp.asarray(bases), jnp.asarray(strides), jnp.asarray(counts)


def _check_lane_support(lanes, configs) -> None:
    int32_max = np.iinfo(np.int32).max
    min_block = min(c.block_bytes for c in configs)
    for trace in lanes:
        total = 0
        for seg in trace:
            base, stride, count = _segment_tuple(seg)
            if count <= 0:
                continue
            total += count
            if stride <= 0 or stride > min_block:
                raise ValueError(
                    f"segment stride {stride} outside (0, {min_block}] — "
                    "the segment-lane engine needs stride <= block_bytes "
                    "in every lane; use segment_sweep_hit_rates for "
                    "sparse-stride traces")
            if base + count * stride > int32_max:
                raise OverflowError(
                    "segment addresses exceed int32 — the lane engine "
                    "keeps metadata in 32-bit; rebase the trace")
        if total > int32_max:
            raise OverflowError(
                f"lane trace has {total} accesses — the lane engine's "
                "global LRU timestamp is int32; split multi-frame sweeps "
                "into per-frame lane calls")


def lane_buckets(configs: list[LLCConfig], waste: int = 2) -> list[list[int]]:
    """Partition lane indices into buckets of comparable set counts so a
    2-set lane doesn't pay a 4096-set lane's padding: lanes sorted by
    descending sets, a new bucket whenever a lane has fewer than
    1/`waste` of its bucket's maximum.  A homogeneous grid stays one
    bucket (one compiled program).  Deterministic for a given config
    list — the campaign executor (``repro.campaign``) also uses it to
    shard sweep points into lane-shaped work units."""
    order = sorted(range(len(configs)), key=lambda i: -configs[i].sets)
    buckets: list[list[int]] = []
    bucket_max = None
    for i in order:
        if bucket_max is None or configs[i].sets * waste < bucket_max:
            buckets.append([])
            bucket_max = configs[i].sets
        buckets[-1].append(i)
    return buckets


def segment_lane_hit_counts(segments, configs: list[LLCConfig]
                            ) -> np.ndarray:
    """(n_cfg, n_segments) exact per-segment LLC hit counts of a
    compressed trace, geometry lanes vmapped into compiled device
    programs.

    ``segments`` is either one shared trace (list of ``Segment``/tuples,
    the Fig. 5 shape: one DBB stream, many geometries) or a list of
    per-lane traces (the Fig. 6 shape: one geometry, many co-runner
    mixes) — per-lane streams are padded to the longest lane with
    count-0 no-op segments.  Unlike ``batched_hits`` the trace is never
    expanded: serial depth is O(segments * max_ways), not O(accesses),
    so full-frame multi-config sweeps are feasible.  Lanes with wildly
    different set counts are bucketed (``_lane_buckets``) so padding
    waste stays bounded — a homogeneous grid is exactly one program.
    Hit counts are bit-identical to the expanded-trace ``batched_hits``
    per lane (tests/test_sweep.py)."""
    per_lane = bool(segments) and isinstance(segments[0], list)
    lanes = segments if per_lane else [list(segments)] * len(configs)
    if per_lane and len(lanes) != len(configs):
        raise ValueError(f"{len(lanes)} lane traces for "
                         f"{len(configs)} configs")
    _check_lane_support(lanes, configs)
    n_seg = max((len(t) for t in lanes), default=0)
    out = np.zeros((len(configs), max(1, n_seg)), np.int64)
    for bucket in lane_buckets(configs):
        cfgs_b = [configs[i] for i in bucket]
        sets, ways, blocks, max_sets, max_ways = _geometry_arrays(cfgs_b)
        engine = _lane_engine(max_sets, max_ways, max_ways, per_lane)
        if per_lane:
            traces_b = [lanes[i] for i in bucket]
            bases, strides, counts = _lane_meta_arrays(traces_b)
            plans = [_lane_plan(t, cfgs_b) for t in traces_b]
            s_pad = bases.shape[1]
            r_needed = np.zeros((len(bucket), s_pad), np.int32)
            cold = np.zeros((len(bucket), s_pad), bool)
            for row, (r, c) in enumerate(plans):
                r_needed[row, :len(r)] = r
                cold[row, :len(c)] = c
            r_needed, cold = jnp.asarray(r_needed), jnp.asarray(cold)
        else:
            bases, strides, counts = (a[0] for a in
                                      _lane_meta_arrays(lanes[:1]))
            r, c = _lane_plan(lanes[0], cfgs_b)
            s_pad = int(bases.shape[0])          # >= 1 even for [] traces
            r_pad_arr = np.zeros(s_pad, np.int32)
            c_pad = np.zeros(s_pad, bool)
            r_pad_arr[:len(r)] = r
            c_pad[:len(c)] = c
            r_needed, cold = jnp.asarray(r_pad_arr), jnp.asarray(c_pad)
        hits = np.asarray(engine(bases, strides, counts, r_needed, cold,
                                 sets, ways, blocks), np.int64)
        for row, i in enumerate(bucket):
            out[i, :hits.shape[1]] = hits[row]
    return out


def segment_lane_hit_rates(segments, configs: list[LLCConfig]
                           ) -> np.ndarray:
    """(n_cfg,) exact hit rates — ``segment_lane_hit_counts`` over the
    per-lane access totals."""
    per_lane = bool(segments) and isinstance(segments[0], list)
    lanes = segments if per_lane else [list(segments)] * len(configs)
    hits = segment_lane_hit_counts(segments, configs).sum(axis=1)
    accesses = np.asarray(
        [max(1, sum(max(0, _segment_tuple(s)[2]) for s in t))
         for t in lanes], np.int64)
    return hits / accesses


def batched_hits_per_trace(byte_addrs_2d, configs: list[LLCConfig]
                           ) -> jax.Array:
    """Like ``batched_hits`` but with one trace per lane (n_cfg, T) —
    used by the interference sweep where co-runners change the trace."""
    sets, ways, blocks, max_sets, max_ways = _geometry_arrays(configs)
    sim = jax.vmap(
        functools.partial(_simulate_padded,
                          max_sets=max_sets, max_ways=max_ways),
        in_axes=(0, 0, 0, 0))
    return sim(as_address_array(byte_addrs_2d, what="DBB trace"),
               sets, ways, blocks)


# --------------------------------------------------------------------------
# Fig. 5 — LLC geometry sweep
# --------------------------------------------------------------------------
def grid_configs(sizes_kib, blocks) -> dict[tuple, LLCConfig]:
    """The Fig. 5 grid's (size, block) -> LLCConfig mapping — delegates
    to ``repro.core.soc.llc_config_for`` so the simulated and
    closed-form sweeps always describe the same geometry."""
    from repro.core.soc import llc_config_for

    return {(size, block): llc_config_for(size, block)
            for block in blocks for size in sizes_kib}


def sweep_llc(sizes_kib=(0.5, 2, 8, 64, 512, 1024, 4096),
              blocks=(32, 64, 128), soc=None,
              window_bursts: int | None = 4096) -> dict:
    """Fig. 5, batched: the closed-form timing grid (`grid`, `no_llc_s`)
    plus exact simulated hit rates for every geometry (`sim_hit_rates`)
    from a single vmapped segment-lane program.

    ``window_bursts=None`` simulates the *entire* YOLOv3 frame (at
    stream granularity — the whole-network compressed trace); an integer
    clips to an arbiter-interleaved window of a representative layer as
    before.  Either way the trace stays compressed end to end: serial
    depth scales with segment count, not burst count."""
    from repro.core.soc import SoCConfig, llc_sweep as _closed_form

    soc = soc or SoCConfig()
    out = _closed_form(sizes_kib=sizes_kib, blocks=blocks, soc=soc)
    cfgs = grid_configs(sizes_kib, blocks)
    if window_bursts is None:
        win = traces.network_trace()
    else:
        win = traces.default_dbb_window(max_bursts=window_bursts)
    rates = segment_lane_hit_rates(win, list(cfgs.values()))
    out["sim_hit_rates"] = {key: float(r)
                            for key, r in zip(cfgs, rates)}
    out["window_bursts"] = traces.total_bursts(win)
    return out


# --------------------------------------------------------------------------
# Fig. 6 — interference sweep
# --------------------------------------------------------------------------
def corunner_segments(llc: LLCConfig, n: int, wss: str,
                      nvdla_segs: list, chunk_bursts: int = 16
                      ) -> tuple[list, np.ndarray]:
    """One lane's interleaved trace, *compressed*: a `chunk_bursts`-burst
    NVDLA chunk, then `chunk_bursts` 64 B write lines from each of `n`
    BwWrite co-runners, round-robin — the DBB/front-bus arbiter at chunk
    granularity.  Returns (segments, nvdla_label_mask); each co-runner's
    stream stays a valid stride run (wraps in its working-set span split
    at the wrap point).  Working sets: "llc" wraps inside half the LLC
    (occupies it), "dram" streams far past it (sweeps it), "l1" never
    reaches the shared fabric (no co-runner accesses)."""
    if wss == "l1":
        n = 0
    chunks = [c for s in nvdla_segs for c in s.split(chunk_bursts)]
    spans_regions = []
    for w in range(n):
        if wss == "llc":
            span = max(64, llc.size_bytes // 2)
            region = 0x4000_0000 + w * 0x0100_0000
        else:                                             # "dram"
            span = llc.size_bytes * 8
            region = 0x6000_0000 + w * 0x0800_0000
        # stagger start banks (2 KiB row offsets) like the NVDLA regions
        # in repro.core.traces — co-runners don't all start on bank 0
        region += (5 + 7 * w) * 2048
        spans_regions.append((span // 64, region))
    cursors = [0] * n
    segs: list[traces.Segment] = []
    labels: list[bool] = []
    for chunk in chunks:
        segs.append(chunk)
        labels.append(True)
        for w in range(n):
            left = chunk.count
            span_lines, region = spans_regions[w]
            while left > 0:                   # split at working-set wrap
                start = cursors[w] % span_lines
                take = min(left, span_lines - start)
                segs.append(traces.Segment(region + start * 64, 64, take,
                                           f"bw{w}"))
                labels.append(False)
                cursors[w] += take
                left -= take
    return segs, np.asarray(labels)


def interference_lane_metrics(llc: LLCConfig, dram, n: int, wss: str,
                              nvdla_segs: list, chunk_bursts: int = 16,
                              t_llc_hit: int = 20) -> dict:
    """One interference lane, simulated exactly and reduced to the flat
    metric record a campaign point journals (``repro.campaign``): the
    co-runner-interleaved compressed trace goes once through the exact
    segment LLC engine (per-segment hit attribution + exact miss runs),
    the miss runs through the closed-form DRAM row model, and the
    latency total through the same closed form as
    ``socsim.simulate_dbb_segments`` — so every field is deterministic
    and internally consistent (the executor's guardrails recompute the
    total from the counts and reject any record where they disagree).

    ``n=0`` (or ``wss="l1"``) is the solo-NVDLA lane.  All values are
    plain ints/floats, JSON-stable for manifest journaling."""
    from repro.core.cache import simulate_segments
    from repro.core.dram import segment_row_hits

    bb = llc.block_bytes
    if dram.row_bytes % bb:
        raise ValueError("row_bytes must be a multiple of block_bytes "
                         "for the segment-native interference lane")
    segs, nv = corunner_segments(llc, n, wss, nvdla_segs, chunk_bursts)
    res = simulate_segments(segs, llc, per_segment=True,
                            collect_miss_runs=True)
    counts = np.asarray([s.count for s in segs], np.int64)
    nv_acc = int(counts[nv].sum())
    nv_hits = int(res.per_segment_hits[nv].sum())
    runs = res.miss_runs
    row = segment_row_hits([(b * bb, bb, c) for b, c, _ in runs], dram)
    run_is_nv = (np.asarray([nv[i] for _, _, i in runs], bool)
                 if runs else np.zeros(0, bool))
    nv_miss = int(sum(c for (_, c, i) in runs if nv[i]))
    nv_row_hits = int(row.per_segment[run_is_nv].sum())
    misses = res.accesses - res.hits
    row_misses = misses - row.row_hits
    total = (res.accesses * t_llc_hit + misses * dram.t_cas_cycles
             + row_misses * (dram.t_rp_cycles + dram.t_rcd_cycles))
    return {
        "segments": len(segs),
        "accesses": int(res.accesses),
        "llc_hits": int(res.hits),
        "dram_row_hits": int(row.row_hits),
        "t_llc_hit": int(t_llc_hit),
        "total_cycles": int(total),
        "hit_rate": res.hits / max(1, res.accesses),
        "nvdla_accesses": nv_acc,
        "nvdla_hits": nv_hits,
        "nvdla_hit_rate": nv_hits / max(1, nv_acc),
        "nvdla_misses": nv_miss,
        "nvdla_miss_row_hits": nv_row_hits,
        "nvdla_miss_row_hit_rate": (nv_row_hits / nv_miss
                                    if nv_miss else 1.0),
    }


def sweep_interference(soc=None, corunners=(0, 1, 2, 3, 4),
                       window_bursts: int = 4096,
                       chunk_bursts: int = 16) -> dict:
    """Fig. 6, batched: closed-form slowdown curves (`l1`/`llc`/`dram`)
    plus, per (wss, n), the *simulated* NVDLA LLC hit rate with
    co-runner write streams physically interleaved into the trace
    (`sim_hit_rates`) — every lane a compressed segment stream.  All
    interference lanes share one LLC geometry, so each lane runs one
    exact segment-engine pass that yields per-segment hit attribution
    *and* the exact LLC-miss runs together (the vmapped
    ``segment_lane_hit_counts`` engine is the multi-*geometry* path;
    replaying here a second time just for lane-parallel hit bits would
    double the simulation cost).  DRAM row-hit rates come from the
    closed-form row model over each lane's miss runs (misses of *all*
    masters mix in the banks, so co-runner misses break the NVDLA
    stream's row locality — the FR-FCFS disruption Fig. 6 attributes
    the "dram" slowdown to)."""
    from repro.core.dram import DRAMConfig
    from repro.core.soc import SoCConfig, interference_sweep as _closed_form

    soc = soc or SoCConfig()
    out = _closed_form(soc=soc, corunners=corunners)
    llc = soc.mem.llc or LLCConfig()
    dram = soc.mem.dram or DRAMConfig()
    if window_bursts is None:
        # full-frame chunk interleaving explodes to ~2M segments/lane —
        # serially infeasible until segment-count compaction lands (see
        # ROADMAP); refuse loudly rather than run for hours
        raise NotImplementedError(
            "full-frame interference sweeps need RLE segment compaction; "
            "pass a window_bursts cap (the LLC sweep supports full "
            "frames — its lanes stay at stream granularity)")
    nvdla_segs = traces.default_dbb_window(max_bursts=window_bursts)
    # l1-fitting co-runners never reach the shared fabric, so every
    # ('l1', n) lane is the solo-NVDLA trace — simulate it once and fan
    # the result out to all n below
    out["sim_hit_rates"] = {}
    out["sim_row_hit_rates"] = {}
    for wss, ns in (("l1", (0,)), ("llc", corunners), ("dram", corunners)):
        for n in ns:
            m = interference_lane_metrics(llc, dram, n, wss, nvdla_segs,
                                          chunk_bursts)
            keys = ([(wss, n)] if wss != "l1"
                    else [("l1", k) for k in corunners])
            for key in keys:
                out["sim_hit_rates"][key] = m["nvdla_hit_rate"]
                out["sim_row_hit_rates"][key] = m["nvdla_miss_row_hit_rate"]
    return out
