"""Batched memory-system sweeps: one compiled program per grid.

The seed path ran every sweep point through its own ``lax.scan`` —
and because ``simulate_trace`` specializes on (sets, ways), every
geometry was a fresh XLA compile.  Two batched engines fix that, both
padding state to the largest geometry and ``jax.vmap``-ing the exact
LLC update over per-lane (sets, ways, block_bytes) scalars so a whole
grid compiles once and runs as a single device program:

* the **per-access engine** (``batched_hits``/``batched_hits_per_trace``)
  scans an expanded byte trace — per-access hit *bits*, serial depth
  O(accesses);
* the **segment-lane engine** (``segment_lane_hit_counts``/``_rates``)
  replays the *compressed* trace of ``repro.core.traces`` directly —
  the geometry-traced segment kernel of ``repro.core.cache`` retires a
  whole (base, stride, count) run per step, so serial depth is
  O(segments * max_ways) and full-frame multi-config sweeps (the trace
  lengths Fig. 5/6 actually need) fit in one program.

Padded ways are masked out of both tag match and victim selection, so
each lane is bit-identical to the unbatched simulator at that geometry
(tests/test_sweep.py).

Public API:
* ``segment_lane_hit_counts``  — (configs, segments) compressed-trace
                                 hit counts, shared or per-lane traces;
* ``segment_lane_hit_rates``   — the per-lane rates thereof;
* ``MixConfig``           — a co-runner mix (count + working-set size);
* ``LaneMetrics``         — frozen typed record of one interference
                            lane (``to_record``/``from_record`` for
                            JSON journaling);
* ``SweepGrid``           — frozen typed result of the figure sweeps;
* ``interference_lane_metrics``       — one lane -> ``LaneMetrics``,
                            optionally LLC way-partitioned
                            (``way_mask=``);
* ``interference_lane_metrics_batch`` — many lanes as vmapped lane
                            programs, optionally sharded over a
                            ``jax.sharding`` mesh (the campaign
                            executor's data-parallel path) and
                            optionally per-lane way-partitioned
                            (``way_masks=``);
* ``partition_way_sels``  — victim/co-runner allocation masks for an
                            Intel-CAT-style two-class way partition;
* ``lane_request_latencies`` — per-victim-chunk memory latencies (the
                            farm's memory-side tail distribution);
* ``sweep_llc``           — Fig. 5 grid: closed-form speedups + exact
                            segment-lane hit rates, windowed or full
                            frame;
* ``sweep_interference``  — Fig. 6 grid: closed-form slowdowns + exact
                            segment-lane hit rates and closed-form DRAM
                            row-hit rates under BwWrite co-runners.

The expanded-trace per-access lanes (``batched_hits`` /
``batched_hits_per_trace``) are deprecated: they serialize on burst
count and exist only as a parity oracle for the segment-lane engine.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import traces
from repro.core.cache import LLCConfig, _append_block_runs
from repro.utils.env import as_address_array


# --------------------------------------------------------------------------
# typed sweep results
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MixConfig:
    """A co-runner mix: how many BwWrite cores run beside the NVDLA and
    how large their working sets are ("l1" never reaches the shared
    fabric, "llc" occupies half the LLC, "dram" streams far past it —
    the three Fig. 6 regimes)."""
    corunners: int = 0
    wss: str = "l1"

    def __post_init__(self):
        if self.wss not in ("l1", "llc", "dram"):
            raise ValueError(f"unknown working-set size {self.wss!r} "
                             "(expected 'l1', 'llc' or 'dram')")
        if self.corunners < 0:
            raise ValueError("corunners must be >= 0")


@dataclasses.dataclass(frozen=True)
class LaneMetrics:
    """One interference lane's exact metric record — the typed currency
    between the sweep engine and the campaign executor (guardrails
    consume attributes, journals store ``to_record()`` dicts).

    Every field is a plain int/float: deterministic, JSON-stable, and
    internally consistent (``total_cycles`` satisfies the closed-form
    latency identity the executor re-checks)."""
    segments: int
    accesses: int
    llc_hits: int
    dram_row_hits: int
    t_llc_hit: int
    total_cycles: int
    hit_rate: float
    nvdla_accesses: int
    nvdla_hits: int
    nvdla_hit_rate: float
    nvdla_misses: int
    nvdla_miss_row_hits: int
    nvdla_miss_row_hit_rate: float

    _INT_FIELDS = ("segments", "accesses", "llc_hits", "dram_row_hits",
                   "t_llc_hit", "total_cycles", "nvdla_accesses",
                   "nvdla_hits", "nvdla_misses", "nvdla_miss_row_hits")
    _FLOAT_FIELDS = ("hit_rate", "nvdla_hit_rate",
                     "nvdla_miss_row_hit_rate")

    def to_record(self) -> dict:
        """Flat JSON-stable dict, keys == field names (the journaled
        point-record format of ``repro.campaign.manifest``)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_record(cls, record: dict) -> "LaneMetrics":
        """Rebuild from a journaled dict.  Raises ``KeyError`` on a
        missing field and ``TypeError``/``ValueError`` on a non-numeric
        one — the executor's replay validation relies on that."""
        kw = {f: int(record[f]) for f in cls._INT_FIELDS}
        kw.update({f: float(record[f]) for f in cls._FLOAT_FIELDS})
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """Typed result of a figure sweep (``sweep_llc`` /
    ``sweep_interference``): the closed-form curves plus the simulated
    per-point rates, with tuple-keyed dicts instead of the old ad-hoc
    string-keyed blob.  ``to_record()`` flattens tuple keys into JSON
    rows ([*key, value]); ``from_record`` restores them exactly."""
    kind: str                              # "llc" | "interference"
    sim_hit_rates: dict                    # (size,block) | (wss,n) -> rate
    window_bursts: int | None = None
    no_llc_s: float | None = None          # Fig. 5 baseline runtime
    speedups: dict | None = None           # (size_kib, block) -> speedup
    slowdowns: dict | None = None          # wss -> {n: slowdown}
    sim_row_hit_rates: dict | None = None  # (wss, n) -> DRAM row-hit rate

    def to_record(self) -> dict:
        rec: dict = {"kind": self.kind, "window_bursts": self.window_bursts,
                     "sim_hit_rates": [[*k, v] for k, v
                                       in self.sim_hit_rates.items()]}
        if self.no_llc_s is not None:
            rec["no_llc_s"] = self.no_llc_s
        if self.speedups is not None:
            rec["speedups"] = [[*k, v] for k, v in self.speedups.items()]
        if self.slowdowns is not None:
            rec["slowdowns"] = [[wss, n, v]
                                for wss, curve in self.slowdowns.items()
                                for n, v in curve.items()]
        if self.sim_row_hit_rates is not None:
            rec["sim_row_hit_rates"] = [[*k, v] for k, v
                                        in self.sim_row_hit_rates.items()]
        return rec

    @classmethod
    def from_record(cls, record: dict) -> "SweepGrid":
        def keyed(rows):
            return {tuple(r[:-1]): r[-1] for r in rows}

        slowdowns = None
        if "slowdowns" in record:
            slowdowns = {}
            for wss, n, v in record["slowdowns"]:
                slowdowns.setdefault(wss, {})[n] = v
        return cls(
            kind=record["kind"],
            window_bursts=record.get("window_bursts"),
            no_llc_s=record.get("no_llc_s"),
            sim_hit_rates=keyed(record["sim_hit_rates"]),
            speedups=(keyed(record["speedups"])
                      if "speedups" in record else None),
            slowdowns=slowdowns,
            sim_row_hit_rates=(keyed(record["sim_row_hit_rates"])
                               if "sim_row_hit_rates" in record else None))


@functools.partial(jax.jit, static_argnames=("max_sets", "max_ways"))
def _simulate_padded(byte_addrs, sets, ways, block_bytes,
                     *, max_sets: int, max_ways: int):
    """Exact LLC scan with *runtime* geometry on padded state.

    sets/ways/block_bytes are traced scalars <= the static paddings.
    LRU is tracked as a last-touch timestamp instead of the reference
    simulator's per-set age counters: the recency *order* (and so every
    victim choice, including the first-index tie-break among untouched
    ways) is identical, but the state update touches one scalar per
    access instead of a whole way row.  Ways >= `ways` never match
    (masked) and never win victim selection (timestamp pinned to
    int32 max), so hits are bit-identical to the unpadded simulator for
    the same geometry."""
    block = byte_addrs // block_bytes
    set_idx = (block % sets).astype(jnp.int32)
    tag = (block // sets).astype(jnp.int32)
    way_mask = jnp.arange(max_ways) < ways
    imax = jnp.iinfo(jnp.int32).max

    def step(carry, inp):
        tags, ts = carry                     # (max_sets, max_ways)
        s, t, k = inp
        row_tags = tags[s]
        row_ts = ts[s]
        match = (row_tags == t) & way_mask
        hit = jnp.any(match)
        victim_ts = jnp.where(way_mask, row_ts, imax)
        way = jnp.where(hit, jnp.argmax(match), jnp.argmin(victim_ts))
        tags = tags.at[s, way].set(t)
        ts = ts.at[s, way].set(k)
        return (tags, ts), hit

    init = (jnp.full((max_sets, max_ways), -1, jnp.int32),
            jnp.zeros((max_sets, max_ways), jnp.int32))
    stamps = jnp.arange(1, byte_addrs.shape[0] + 1, dtype=jnp.int32)
    _, hits = jax.lax.scan(step, init, (set_idx, tag, stamps))
    return hits


def _geometry_arrays(configs):
    sets = jnp.asarray([c.sets for c in configs], jnp.int32)
    ways = jnp.asarray([c.ways for c in configs], jnp.int32)
    blocks = jnp.asarray([c.block_bytes for c in configs], jnp.int32)
    max_sets = max(c.sets for c in configs)
    max_ways = max(c.ways for c in configs)
    return sets, ways, blocks, max_sets, max_ways


_EXPANDED_TRACE_DEPRECATION = (
    "the expanded-trace per-access lanes are deprecated: serial depth is "
    "O(accesses) per lane.  Use the segment-lane API "
    "(segment_lane_hit_counts / segment_lane_hit_rates / "
    "interference_lane_metrics_batch) which replays the compressed trace "
    "directly.")


def batched_hits(byte_addrs, configs: list[LLCConfig]) -> jax.Array:
    """(n_cfg, T) per-access hit bits — every lane bit-identical to the
    unbatched ``simulate_trace`` at that geometry, one compile total.

    .. deprecated:: kept only as a parity oracle for the segment-lane
       engine; use ``segment_lane_hit_counts``."""
    warnings.warn(_EXPANDED_TRACE_DEPRECATION, DeprecationWarning,
                  stacklevel=2)
    return _batched_hits(byte_addrs, configs)


def _batched_hits(byte_addrs, configs: list[LLCConfig]) -> jax.Array:
    sets, ways, blocks, max_sets, max_ways = _geometry_arrays(configs)
    addrs = as_address_array(byte_addrs, what="DBB trace")
    sim = jax.vmap(
        functools.partial(_simulate_padded,
                          max_sets=max_sets, max_ways=max_ways),
        in_axes=(None, 0, 0, 0))
    return sim(addrs, sets, ways, blocks)


def batched_hit_rates(byte_addrs, configs: list[LLCConfig]) -> jax.Array:
    warnings.warn(_EXPANDED_TRACE_DEPRECATION, DeprecationWarning,
                  stacklevel=2)
    return jnp.mean(_batched_hits(byte_addrs, configs).astype(jnp.float32),
                    axis=1)


def segment_sweep_hit_rates(segments, configs: list[LLCConfig]
                            ) -> np.ndarray:
    """(n_cfg,) exact hit rates of one *compressed* trace — each config
    replayed through the segment engine (closed form / per-set rounds),
    so whole-network windows are feasible where per-access expansion is
    not.  Exactly ``hit_rate`` of the expanded trace, per config."""
    from repro.core.cache import simulate_segments

    return np.asarray([simulate_segments(segments, c).hit_rate
                       for c in configs], np.float64)


# --------------------------------------------------------------------------
# segment-lane engine: vmapped segment replay over runtime geometry
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=32)
def _lane_engine(max_sets: int, max_ways: int, r_pad: int,
                 per_lane_trace: bool, collect: bool = False,
                 suffix: str = "full", masked: bool = False):
    from repro.core.cache import segment_lane_scan

    if masked and not per_lane_trace:
        raise ValueError("way-masked lanes need per-lane traces "
                         "(each lane carries its own way_sels)")
    in_axes = ((0, 0, 0, 0, 0, 0, 0, 0) if per_lane_trace
               else (None, None, None, None, None, 0, 0, 0))
    if masked:
        in_axes = in_axes + (0,)
    return jax.jit(jax.vmap(
        functools.partial(segment_lane_scan, max_sets=max_sets,
                          max_ways=max_ways, r_pad=r_pad, collect=collect,
                          suffix=suffix),
        in_axes=in_axes))


@functools.lru_cache(maxsize=32)
def _single_lane_engine(max_sets: int, max_ways: int, r_pad: int,
                        suffix: str, return_state: bool = False):
    """One jitted (unvmapped) masked lane — the way-partitioned QoS
    path and the per-request latency attribution both run single
    lanes at exact geometry."""
    from repro.core.cache import segment_lane_scan

    return jax.jit(functools.partial(
        segment_lane_scan, max_sets=max_sets, max_ways=max_ways,
        r_pad=r_pad, collect=True, suffix=suffix,
        return_state=return_state))


def _lane_plan(trace: list, configs: list[LLCConfig]
               ) -> tuple[np.ndarray, np.ndarray]:
    """Host-side execution plan for one segment stream over a lane
    bucket: per segment, the round-scan rounds needed (max across the
    bucket's geometries — extra rounds in other lanes are masked no-ops)
    and whether the segment is provably cold (byte range disjoint, with
    block-alignment slack, from every earlier segment — all its arrivals
    miss in every lane, so the closed form needs no rounds at all)."""
    from repro.core.cache import _TouchedBlocks

    metas = [_segment_tuple(s) for s in trace]
    base = np.asarray([m[0] for m in metas], np.int64)
    stride = np.asarray([m[1] for m in metas], np.int64)
    count = np.asarray([m[2] for m in metas], np.int64)
    live = count > 0
    last = base + np.maximum(count - 1, 0) * stride
    slack = max(c.block_bytes for c in configs) - 1
    touched = _TouchedBlocks()
    cold = np.zeros(len(metas), bool)
    for j in range(len(metas)):
        if not live[j]:
            continue
        lo, hi = int(base[j] - slack), int(last[j] + slack)
        cold[j] = not touched.overlaps(lo, hi)
        touched.add(lo, hi)
    r = np.zeros(len(metas), np.int64)
    for c in configs:
        nb = last // c.block_bytes - base // c.block_bytes + 1
        r = np.maximum(r, np.minimum(c.ways, -(-nb // c.sets)))
    r = np.where(live & ~cold, r, 0)
    return r.astype(np.int32), cold


_segment_tuple = traces.segment_tuple


def _lane_meta_arrays(lanes: list[list]) -> tuple:
    """Per-lane segment streams -> (n_lane, max_segments) int32 metadata
    arrays, padded with count == 0 no-op segments."""
    n_seg = max((len(t) for t in lanes), default=0)
    shape = (len(lanes), max(1, n_seg))
    bases = np.zeros(shape, np.int32)
    strides = np.ones(shape, np.int32)
    counts = np.zeros(shape, np.int32)
    for i, trace in enumerate(lanes):
        for j, seg in enumerate(trace):
            bases[i, j], strides[i, j], counts[i, j] = _segment_tuple(seg)
    return jnp.asarray(bases), jnp.asarray(strides), jnp.asarray(counts)


def _check_lane_support(lanes, configs) -> None:
    int32_max = np.iinfo(np.int32).max
    min_block = min(c.block_bytes for c in configs)
    for trace in lanes:
        total = 0
        for seg in trace:
            base, stride, count = _segment_tuple(seg)
            if count <= 0:
                continue
            total += count
            if stride <= 0 or stride > min_block:
                raise ValueError(
                    f"segment stride {stride} outside (0, {min_block}] — "
                    "the segment-lane engine needs stride <= block_bytes "
                    "in every lane; use segment_sweep_hit_rates for "
                    "sparse-stride traces")
            if base + count * stride > int32_max:
                raise OverflowError(
                    "segment addresses exceed int32 — the lane engine "
                    "keeps metadata in 32-bit; rebase the trace")
        if total > int32_max:
            raise OverflowError(
                f"lane trace has {total} accesses — the lane engine's "
                "global LRU timestamp is int32; split multi-frame sweeps "
                "into per-frame lane calls")


def _check_lane_support_meta(lanes_meta, configs) -> None:
    """`_check_lane_support` over (bases, strides, counts) array lanes —
    the same constraints, vectorized."""
    int32_max = np.iinfo(np.int32).max
    min_block = min(c.block_bytes for c in configs)
    for base, stride, count in lanes_meta:
        live = count > 0
        bad = live & ((stride <= 0) | (stride > min_block))
        if np.any(bad):
            raise ValueError(
                f"segment stride {int(stride[bad][0])} outside "
                f"(0, {min_block}] — the segment-lane engine needs "
                "stride <= block_bytes in every lane; use "
                "segment_sweep_hit_rates for sparse-stride traces")
        if np.any(live & (base + count * stride > int32_max)):
            raise OverflowError(
                "segment addresses exceed int32 — the lane engine "
                "keeps metadata in 32-bit; rebase the trace")
        if int(count[live].sum()) > int32_max:
            raise OverflowError(
                f"lane trace has {int(count[live].sum())} accesses — "
                "the lane engine's global LRU timestamp is int32; split "
                "multi-frame sweeps into per-frame lane calls")


def lane_buckets(configs: list[LLCConfig], waste: int = 2) -> list[list[int]]:
    """Partition lane indices into buckets of comparable set counts so a
    2-set lane doesn't pay a 4096-set lane's padding: lanes sorted by
    descending sets, a new bucket whenever a lane has fewer than
    1/`waste` of its bucket's maximum.  A homogeneous grid stays one
    bucket (one compiled program).  Deterministic for a given config
    list — the campaign executor (``repro.campaign``) also uses it to
    shard sweep points into lane-shaped work units."""
    order = sorted(range(len(configs)), key=lambda i: -configs[i].sets)
    buckets: list[list[int]] = []
    bucket_max = None
    for i in order:
        if bucket_max is None or configs[i].sets * waste < bucket_max:
            buckets.append([])
            bucket_max = configs[i].sets
        buckets[-1].append(i)
    return buckets


def segment_lane_hit_counts(segments, configs: list[LLCConfig]
                            ) -> np.ndarray:
    """(n_cfg, n_segments) exact per-segment LLC hit counts of a
    compressed trace, geometry lanes vmapped into compiled device
    programs.

    ``segments`` is either one shared trace (list of ``Segment``/tuples,
    the Fig. 5 shape: one DBB stream, many geometries) or a list of
    per-lane traces (the Fig. 6 shape: one geometry, many co-runner
    mixes) — per-lane streams are padded to the longest lane with
    count-0 no-op segments.  Unlike ``batched_hits`` the trace is never
    expanded: serial depth is O(segments * max_ways), not O(accesses),
    so full-frame multi-config sweeps are feasible.  Lanes with wildly
    different set counts are bucketed (``_lane_buckets``) so padding
    waste stays bounded — a homogeneous grid is exactly one program.
    Hit counts are bit-identical to the expanded-trace ``batched_hits``
    per lane (tests/test_sweep.py)."""
    per_lane = bool(segments) and isinstance(segments[0], list)
    lanes = segments if per_lane else [list(segments)] * len(configs)
    if per_lane and len(lanes) != len(configs):
        raise ValueError(f"{len(lanes)} lane traces for "
                         f"{len(configs)} configs")
    _check_lane_support(lanes, configs)
    n_seg = max((len(t) for t in lanes), default=0)
    out = np.zeros((len(configs), max(1, n_seg)), np.int64)
    for bucket in lane_buckets(configs):
        cfgs_b = [configs[i] for i in bucket]
        sets, ways, blocks, max_sets, max_ways = _geometry_arrays(cfgs_b)
        engine = _lane_engine(max_sets, max_ways, max_ways, per_lane)
        if per_lane:
            traces_b = [lanes[i] for i in bucket]
            bases, strides, counts = _lane_meta_arrays(traces_b)
            plans = [_lane_plan(t, cfgs_b) for t in traces_b]
            s_pad = bases.shape[1]
            r_needed = np.zeros((len(bucket), s_pad), np.int32)
            cold = np.zeros((len(bucket), s_pad), bool)
            for row, (r, c) in enumerate(plans):
                r_needed[row, :len(r)] = r
                cold[row, :len(c)] = c
            r_needed, cold = jnp.asarray(r_needed), jnp.asarray(cold)
        else:
            bases, strides, counts = (a[0] for a in
                                      _lane_meta_arrays(lanes[:1]))
            r, c = _lane_plan(lanes[0], cfgs_b)
            s_pad = int(bases.shape[0])          # >= 1 even for [] traces
            r_pad_arr = np.zeros(s_pad, np.int32)
            c_pad = np.zeros(s_pad, bool)
            r_pad_arr[:len(r)] = r
            c_pad[:len(c)] = c
            r_needed, cold = jnp.asarray(r_pad_arr), jnp.asarray(c_pad)
        hits = np.asarray(engine(bases, strides, counts, r_needed, cold,
                                 sets, ways, blocks), np.int64)
        for row, i in enumerate(bucket):
            out[i, :hits.shape[1]] = hits[row]
    return out


def segment_lane_hit_rates(segments, configs: list[LLCConfig]
                           ) -> np.ndarray:
    """(n_cfg,) exact hit rates — ``segment_lane_hit_counts`` over the
    per-lane access totals."""
    per_lane = bool(segments) and isinstance(segments[0], list)
    lanes = segments if per_lane else [list(segments)] * len(configs)
    hits = segment_lane_hit_counts(segments, configs).sum(axis=1)
    accesses = np.asarray(
        [max(1, sum(max(0, _segment_tuple(s)[2]) for s in t))
         for t in lanes], np.int64)
    return hits / accesses


def batched_hits_per_trace(byte_addrs_2d, configs: list[LLCConfig]
                           ) -> jax.Array:
    """Like ``batched_hits`` but with one trace per lane (n_cfg, T).

    .. deprecated:: the interference sweep now feeds compressed
       co-runner lanes to the segment engine
       (``interference_lane_metrics_batch``)."""
    warnings.warn(_EXPANDED_TRACE_DEPRECATION, DeprecationWarning,
                  stacklevel=2)
    sets, ways, blocks, max_sets, max_ways = _geometry_arrays(configs)
    sim = jax.vmap(
        functools.partial(_simulate_padded,
                          max_sets=max_sets, max_ways=max_ways),
        in_axes=(0, 0, 0, 0))
    return sim(as_address_array(byte_addrs_2d, what="DBB trace"),
               sets, ways, blocks)


# --------------------------------------------------------------------------
# Fig. 5 — LLC geometry sweep
# --------------------------------------------------------------------------
def grid_configs(sizes_kib, blocks) -> dict[tuple, LLCConfig]:
    """The Fig. 5 grid's (size, block) -> LLCConfig mapping — delegates
    to ``repro.core.soc.llc_config_for`` so the simulated and
    closed-form sweeps always describe the same geometry."""
    from repro.core.soc import llc_config_for

    return {(size, block): llc_config_for(size, block)
            for block in blocks for size in sizes_kib}


def sweep_llc(sizes_kib=(0.5, 2, 8, 64, 512, 1024, 4096),
              blocks=(32, 64, 128), *, soc=None,
              window_bursts: int | None = 4096) -> SweepGrid:
    """Fig. 5, batched: the closed-form timing grid (``.speedups``,
    ``.no_llc_s``) plus exact simulated hit rates for every geometry
    (``.sim_hit_rates``) from a single vmapped segment-lane program,
    as a typed ``SweepGrid``.

    ``window_bursts=None`` simulates the *entire* YOLOv3 frame (at
    stream granularity — the whole-network compressed trace); an integer
    clips to an arbiter-interleaved window of a representative layer as
    before.  Either way the trace stays compressed end to end: serial
    depth scales with segment count, not burst count."""
    from repro.core.soc import SoCConfig, llc_sweep as _closed_form

    soc = soc or SoCConfig()
    cf = _closed_form(sizes_kib=sizes_kib, blocks=blocks, soc=soc)
    cfgs = grid_configs(sizes_kib, blocks)
    if window_bursts is None:
        win = traces.network_trace()
    else:
        win = traces.default_dbb_window(max_bursts=window_bursts)
    rates = segment_lane_hit_rates(win, list(cfgs.values()))
    return SweepGrid(
        kind="llc",
        no_llc_s=cf["no_llc_s"],
        speedups=cf["grid"],
        sim_hit_rates={key: float(r) for key, r in zip(cfgs, rates)},
        window_bursts=traces.total_bursts(win))


# --------------------------------------------------------------------------
# Fig. 6 — interference sweep
# --------------------------------------------------------------------------
def corunner_segments(nvdla_segs: list, *, llc: LLCConfig,
                      mix: MixConfig, chunk_bursts: int = 16
                      ) -> tuple[list, np.ndarray]:
    """One lane's interleaved trace, *compressed*: a `chunk_bursts`-burst
    NVDLA chunk, then `chunk_bursts` 64 B write lines from each of the
    mix's `corunners` BwWrite cores, round-robin — the DBB/front-bus
    arbiter at chunk granularity.  Returns (segments,
    nvdla_label_mask); each co-runner's stream stays a valid stride run
    (wraps in its working-set span split at the wrap point).  Working
    sets: "llc" wraps inside half the LLC (occupies it), "dram" streams
    far past it (sweeps it), "l1" never reaches the shared fabric (no
    co-runner accesses)."""
    n = 0 if mix.wss == "l1" else mix.corunners
    chunks = [c for s in nvdla_segs for c in s.split(chunk_bursts)]
    spans_regions = _corunner_spans(llc, mix)
    cursors = [0] * n
    segs: list[traces.Segment] = []
    labels: list[bool] = []
    for chunk in chunks:
        segs.append(chunk)
        labels.append(True)
        for w in range(n):
            left = chunk.count
            span_lines, region = spans_regions[w]
            while left > 0:                   # split at working-set wrap
                start = cursors[w] % span_lines
                take = min(left, span_lines - start)
                segs.append(traces.Segment(region + start * 64, 64, take,
                                           f"bw{w}"))
                labels.append(False)
                cursors[w] += take
                left -= take
    return segs, np.asarray(labels)


def _corunner_spans(llc: LLCConfig, mix: MixConfig) -> list[tuple[int, int]]:
    """Each co-runner's (span_lines, region_base) — the one definition
    ``corunner_segments`` and ``corunner_meta`` share."""
    n = 0 if mix.wss == "l1" else mix.corunners
    spans_regions = []
    for w in range(n):
        if mix.wss == "llc":
            span = max(64, llc.size_bytes // 2)
            region = 0x4000_0000 + w * 0x0100_0000
        else:                                             # "dram"
            span = llc.size_bytes * 8
            region = 0x6000_0000 + w * 0x0800_0000
        # stagger start banks (2 KiB row offsets) like the NVDLA regions
        # in repro.core.traces — co-runners don't all start on bank 0
        region += (5 + 7 * w) * 2048
        spans_regions.append((span // 64, region))
    return spans_regions


def nvdla_chunks(nvdla_segs: list, chunk_bursts: int = 16) -> tuple:
    """The chunked NVDLA stream as ``(bases, strides, counts)`` int64
    arrays — ``Segment.split(chunk_bursts)`` over the whole window,
    array-native.  Depends only on the trace, not the lane's geometry
    or mix, so batched callers compute it once per shard and pass it to
    every ``corunner_meta`` call (``_chunks``)."""
    cb, cs, cc = [], [], []
    for s in nvdla_segs:
        base, stride, count = _segment_tuple(s)
        if count <= 0:
            continue
        n_ch = -(-count // chunk_bursts)
        idx = np.arange(n_ch, dtype=np.int64)
        cb.append(base + idx * (chunk_bursts * stride))
        cs.append(np.full(n_ch, stride, np.int64))
        cnt = np.full(n_ch, chunk_bursts, np.int64)
        cnt[-1] = count - (n_ch - 1) * chunk_bursts
        cc.append(cnt)
    if not cb:
        z = np.zeros(0, np.int64)
        return z, z.copy(), z.copy()
    return tuple(np.concatenate(a) for a in (cb, cs, cc))


def corunner_meta(nvdla_segs: list, *, llc: LLCConfig, mix: MixConfig,
                  chunk_bursts: int = 16, _chunks: tuple | None = None
                  ) -> tuple:
    """Array-native twin of ``corunner_segments``: the same interleaved
    lane trace as ``(bases, strides, counts, nvdla_mask)`` int64/bool
    numpy arrays — segment for segment identical to
    ``[segment_tuple(s) for s in corunner_segments(...)[0]]`` — built
    with no per-segment Python objects, so the batched lane path's
    trace construction is O(numpy) instead of O(segments) interpreter
    work.  ``_chunks`` takes a precomputed ``nvdla_chunks`` result
    (lane-invariant, so batch callers share one).  Falls back to
    materializing ``corunner_segments`` when a co-runner chunk wraps
    its working set more than once (spans smaller than a chunk)."""
    n, wss = mix.corunners, mix.wss
    if wss == "l1":
        n = 0
    cb, cs, cc = (_chunks if _chunks is not None
                  else nvdla_chunks(nvdla_segs, chunk_bursts))
    if cb.shape[0] == 0:
        z = np.zeros(0, np.int64)
        return z, z.copy(), z.copy(), np.zeros(0, bool)
    n_ch = cb.shape[0]
    if n == 0:
        return cb, cs, cc, np.ones(n_ch, bool)
    pre = np.concatenate([[0], np.cumsum(cc)[:-1]])   # cursor before chunk
    chunk_i = np.arange(n_ch, dtype=np.int64)
    parts = [(cb, cs, cc, chunk_i, np.zeros(n_ch, np.int64), True)]
    for w, (span_lines, region) in enumerate(_corunner_spans(llc, mix)):
        start = pre % span_lines
        take1 = np.minimum(cc, span_lines - start)
        rest = cc - take1
        if np.any(rest > span_lines):     # >2 wraps: rare tiny spans
            segs, nv = corunner_segments(nvdla_segs, llc=llc, mix=mix,
                                         chunk_bursts=chunk_bursts)
            m = np.asarray([_segment_tuple(sg) for sg in segs],
                           np.int64).reshape(-1, 3)
            return m[:, 0], m[:, 1], m[:, 2], np.asarray(nv, bool)
        s64 = np.full(n_ch, 64, np.int64)
        parts.append((region + start * 64, s64, take1, chunk_i,
                      np.full(n_ch, 1 + 2 * w, np.int64), False))
        j2 = np.flatnonzero(rest > 0)
        if j2.size:
            parts.append((np.full(j2.size, region, np.int64),
                          np.full(j2.size, 64, np.int64), rest[j2], j2,
                          np.full(j2.size, 2 + 2 * w, np.int64), False))
    bases = np.concatenate([p[0] for p in parts])
    strides = np.concatenate([p[1] for p in parts])
    counts = np.concatenate([p[2] for p in parts])
    chunks = np.concatenate([p[3] for p in parts])
    slots = np.concatenate([p[4] for p in parts])
    nv = np.concatenate([np.full(p[0].shape[0], p[5], bool)
                         for p in parts])
    order = np.lexsort((slots, chunks))   # chunk-major, arbiter slots
    return bases[order], strides[order], counts[order], nv[order]


def _lane_metrics_from_runs(*, n_segments, accesses, hits, runs, bb, nv,
                            dram, t_llc_hit, nv_acc, nv_hits) -> LaneMetrics:
    """The shared lane reduction: exact LLC counts + miss runs
    ((first_block, n_blocks, seg_idx) triples in access order, either a
    list of tuples or a tuple of three aligned int64 arrays) ->
    closed-form DRAM row hits -> closed-form latency total -> the typed
    record.  Both the sequential and the batched path end here, so
    their metrics are bit-identical by construction."""
    from repro.core.dram import segment_row_hits

    if isinstance(runs, tuple):
        fb, nbk, sidx = (np.asarray(a, np.int64) for a in runs)
    else:
        arr = np.asarray(runs, np.int64).reshape(-1, 3)
        fb, nbk, sidx = arr[:, 0], arr[:, 1], arr[:, 2]
    row = segment_row_hits((fb * bb, np.full(fb.shape[0], bb, np.int64),
                            nbk), dram)
    run_is_nv = np.asarray(nv, bool)[sidx]
    nv_miss = int(nbk[run_is_nv].sum())
    nv_row_hits = int(row.per_segment[run_is_nv].sum())
    misses = accesses - hits
    row_misses = misses - row.row_hits
    total = (accesses * t_llc_hit + misses * dram.t_cas_cycles
             + row_misses * (dram.t_rp_cycles + dram.t_rcd_cycles))
    return LaneMetrics(
        segments=n_segments,
        accesses=int(accesses),
        llc_hits=int(hits),
        dram_row_hits=int(row.row_hits),
        t_llc_hit=int(t_llc_hit),
        total_cycles=int(total),
        hit_rate=hits / max(1, accesses),
        nvdla_accesses=nv_acc,
        nvdla_hits=nv_hits,
        nvdla_hit_rate=nv_hits / max(1, nv_acc),
        nvdla_misses=nv_miss,
        nvdla_miss_row_hits=nv_row_hits,
        nvdla_miss_row_hit_rate=(nv_row_hits / nv_miss
                                 if nv_miss else 1.0))


def _check_row_block(llc: LLCConfig, dram) -> None:
    if dram.row_bytes % llc.block_bytes:
        raise ValueError("row_bytes must be a multiple of block_bytes "
                         "for the segment-native interference lane")


def partition_way_sels(nv_mask, llc: LLCConfig, way_mask: int) -> np.ndarray:
    """Per-segment allocation masks for an LLC way partition: the
    victim (NVDLA/NPU) segments allocate only into ``way_mask``'s ways,
    co-runner segments into the complement — Intel-CAT-style two-class
    partitioning.  ``way_mask == (1 << ways) - 1`` (the full mask)
    means *no* partition: both classes allocate anywhere, bit-exactly
    the unpartitioned scan (the invariant tests/test_waymask.py pins).

    Raises ``ValueError`` when the victim mask selects no real way —
    an empty partition cannot allocate."""
    full = (1 << llc.ways) - 1
    vm = int(way_mask) & full
    if vm == 0:
        raise ValueError(
            f"way_mask {way_mask:#x} selects none of the {llc.ways} "
            "ways — the victim partition must hold at least one way")
    co = full & ~vm
    if co == 0:
        co = full        # full victim mask == unpartitioned for everyone
    return np.where(np.asarray(nv_mask, bool), vm, co).astype(np.int32)


def _masked_lane_run(b, s, c, llc: LLCConfig, way_sels,
                     *, return_state: bool = False):
    """One way-partitioned lane through the masked segment kernel:
    every segment carries a non-zero allocation mask, so the plan gives
    every segment its full ``ceil(n_blocks / sets)`` rounds (no closed
    -form suffix — the suffix assumes unrestricted victim cycling) and
    miss runs are reconstructed with ``full_prefix=True``.  Returns
    (per_segment_hits, miss_run_arrays[, final_state])."""
    bb, sets, ways = llc.block_bytes, llc.sets, llc.ways
    live = c > 0
    last = b + np.maximum(c - 1, 0) * s
    nb = np.where(live, last // bb - b // bb + 1, 0)
    r_needed = (-(-nb // sets)).astype(np.int32)
    r_pad = max(1, int(r_needed.max(initial=1)))
    cold = np.zeros(b.shape[0], bool)
    engine = _single_lane_engine(sets, ways, r_pad, "none",
                                 return_state=return_state)
    out = engine(jnp.asarray(b, jnp.int32), jnp.asarray(s, jnp.int32),
                 jnp.asarray(c, jnp.int32), jnp.asarray(r_needed),
                 jnp.asarray(cold), sets, ways, bb,
                 jnp.asarray(way_sels, jnp.int32))
    hits = np.asarray(out[0], np.int64)
    runs = _lane_miss_runs(b, s, c, llc, cold, np.asarray(out[1]),
                           full_prefix=True)
    if return_state:
        return hits, runs, jax.tree.map(np.asarray, out[2])
    return hits, runs


def interference_lane_metrics(nvdla_segs: list, *, llc: LLCConfig,
                              dram, mix: MixConfig,
                              chunk_bursts: int = 16,
                              t_llc_hit: int = 20,
                              way_mask: int | None = None) -> LaneMetrics:
    """One interference lane, simulated exactly and reduced to the typed
    ``LaneMetrics`` record a campaign point journals
    (``repro.campaign``): the co-runner-interleaved compressed trace
    goes once through the exact segment LLC engine (per-segment hit
    attribution + exact miss runs), the miss runs through the
    closed-form DRAM row model, and the latency total through the same
    closed form as ``socsim.simulate_dbb_segments`` — so every field is
    deterministic and internally consistent (the executor's guardrails
    recompute the total from the counts and reject any record where
    they disagree).

    ``mix.corunners=0`` (or ``mix.wss="l1"``) is the solo-NVDLA lane.

    ``way_mask`` turns on LLC way partitioning (``partition_way_sels``):
    victim segments allocate only into ``way_mask``'s ways, co-runners
    into the complement.  The full mask is bit-exactly the
    unpartitioned lane."""
    from repro.core.cache import simulate_segments

    bb = llc.block_bytes
    _check_row_block(llc, dram)
    if way_mask is not None:
        b, s, c, nv = corunner_meta(nvdla_segs, llc=llc, mix=mix,
                                    chunk_bursts=chunk_bursts)
        _check_lane_support_meta([(b, s, c)], [llc])
        way_sels = partition_way_sels(nv, llc, way_mask)
        hits, runs = _masked_lane_run(b, s, c, llc, way_sels)
        n_seg = c.shape[0]
        accesses = int(c.sum())
        lane_hits = int(hits[:n_seg].sum())
        if int(runs[1].sum()) != accesses - lane_hits:
            raise RuntimeError(
                "masked lane miss-run reconstruction disagrees with the "
                f"kernel: {int(runs[1].sum())} missed blocks vs "
                f"{accesses - lane_hits} misses")
        return _lane_metrics_from_runs(
            n_segments=n_seg, accesses=accesses, hits=lane_hits,
            runs=runs, bb=bb, nv=nv, dram=dram, t_llc_hit=t_llc_hit,
            nv_acc=int(c[nv].sum()),
            nv_hits=int(hits[:n_seg][nv].sum()))
    segs, nv = corunner_segments(nvdla_segs, llc=llc, mix=mix,
                                 chunk_bursts=chunk_bursts)
    res = simulate_segments(segs, llc, per_segment=True,
                            collect_miss_runs=True)
    counts = np.asarray([s.count for s in segs], np.int64)
    return _lane_metrics_from_runs(
        n_segments=len(segs), accesses=int(res.accesses),
        hits=int(res.hits), runs=res.miss_runs, bb=bb,
        nv=nv, dram=dram, t_llc_hit=t_llc_hit,
        nv_acc=int(counts[nv].sum()),
        nv_hits=int(res.per_segment_hits[nv].sum()))


def lane_request_latencies(nvdla_segs: list, *, llc: LLCConfig, dram,
                           mix: MixConfig, chunk_bursts: int = 16,
                           t_llc_hit: int = 20,
                           way_mask: int | None = None
                           ) -> tuple[np.ndarray, LaneMetrics]:
    """Per-victim-chunk memory latencies of one interference lane — the
    memory half of the farm's tail-latency distribution
    (``repro.core.farm``).

    The lane's closed-form latency identity is linear in per-segment
    counters (``accesses * t_llc_hit + misses * tCAS + row_misses *
    (tRP + tRCD)``), so it distributes exactly over segments: each
    segment's share uses its own access/hit counts plus its row hits
    (attributed from the lane's miss runs).  ``corunner_segments``
    emits exactly one victim segment per ``chunk_bursts``-burst chunk,
    so the victim rows *are* the per-chunk service latencies — returned
    in stream order alongside the lane's ``LaneMetrics``.  The
    per-chunk latencies provably sum to ``metrics.total_cycles`` (the
    identity's linearity; asserted here).

    ``way_mask`` partitions the LLC as in
    ``interference_lane_metrics``."""
    from repro.core.cache import simulate_segments
    from repro.core.dram import segment_row_hits

    bb = llc.block_bytes
    _check_row_block(llc, dram)
    if way_mask is not None:
        b, s, c, nv = corunner_meta(nvdla_segs, llc=llc, mix=mix,
                                    chunk_bursts=chunk_bursts)
        _check_lane_support_meta([(b, s, c)], [llc])
        way_sels = partition_way_sels(nv, llc, way_mask)
        hits, runs = _masked_lane_run(b, s, c, llc, way_sels)
        counts = np.asarray(c, np.int64)
        hits = np.asarray(hits[:counts.shape[0]], np.int64)
    else:
        segs, nv = corunner_segments(nvdla_segs, llc=llc, mix=mix,
                                     chunk_bursts=chunk_bursts)
        res = simulate_segments(segs, llc, per_segment=True,
                                collect_miss_runs=True)
        counts = np.asarray([sg.count for sg in segs], np.int64)
        hits = np.asarray(res.per_segment_hits, np.int64)
        runs = res.miss_runs
    if isinstance(runs, tuple):
        fb, nbk, sidx = (np.asarray(a, np.int64) for a in runs)
    else:
        arr = np.asarray(runs, np.int64).reshape(-1, 3)
        fb, nbk, sidx = arr[:, 0], arr[:, 1], arr[:, 2]
    row = segment_row_hits((fb * bb, np.full(fb.shape[0], bb, np.int64),
                            nbk), dram)
    seg_row = np.zeros(counts.shape[0], np.int64)
    np.add.at(seg_row, sidx, np.asarray(row.per_segment, np.int64))
    misses = counts - hits
    per_seg = (counts * t_llc_hit + misses * dram.t_cas_cycles
               + (misses - seg_row) * (dram.t_rp_cycles
                                       + dram.t_rcd_cycles))
    metrics = _lane_metrics_from_runs(
        n_segments=counts.shape[0], accesses=int(counts.sum()),
        hits=int(hits.sum()), runs=(fb, nbk, sidx), bb=bb, nv=nv,
        dram=dram, t_llc_hit=t_llc_hit, nv_acc=int(counts[nv].sum()),
        nv_hits=int(hits[nv].sum()))
    if int(per_seg.sum()) != metrics.total_cycles:
        raise RuntimeError(
            "per-segment latency attribution does not sum to the lane "
            f"total: {int(per_seg.sum())} vs {metrics.total_cycles}")
    return per_seg[np.asarray(nv, bool)], metrics


def _marginal_lane_metrics(full: LaneMetrics, warm: LaneMetrics
                           ) -> LaneMetrics:
    """Counter-wise difference of two lane records (full − warm), with
    the derived rates recomputed from the differenced counters.  Exact
    whenever ``warm``'s trace is a prefix of ``full``'s: the LLC engine
    and the DRAM open-row carry are both left-to-right, so the prefix's
    counters are unchanged by what follows and subtraction isolates the
    suffix — including the closed-form latency identity, which is linear
    in the counters."""
    d = {f: getattr(full, f) - getattr(warm, f)
         for f in LaneMetrics._INT_FIELDS if f != "t_llc_hit"}
    if full.t_llc_hit != warm.t_llc_hit:
        raise ValueError("marginal lane metrics need matching t_llc_hit")
    nv_miss = d["nvdla_misses"]
    return LaneMetrics(
        t_llc_hit=full.t_llc_hit,
        hit_rate=d["llc_hits"] / max(1, d["accesses"]),
        nvdla_hit_rate=d["nvdla_hits"] / max(1, d["nvdla_accesses"]),
        nvdla_miss_row_hit_rate=(d["nvdla_miss_row_hits"] / nv_miss
                                 if nv_miss else 1.0),
        **d)


def step_lane_metrics(segments: list, *, llc: LLCConfig, dram,
                      mix: MixConfig | None = None,
                      warm_prefix: list | None = None,
                      chunk_bursts: int = 16,
                      t_llc_hit: int = 20) -> LaneMetrics:
    """One scheduler step's DBB stream reduced to a typed lane record —
    the reusable step-latency entry point behind ``repro.serve``.

    Without ``warm_prefix`` this is a cold-cache
    ``interference_lane_metrics`` lane.  With it, the step is simulated
    *after* the prefix (LLC state and DRAM open rows warmed by it, the
    co-runner interleave continuing causally across the boundary) and
    the returned record is the exact marginal cost of the step:
    ``sim(prefix + step) − sim(prefix)``.  Passing the step trace itself
    as its own warm prefix yields the steady-state per-step cost of a
    periodic working set — which is how a serving engine's decode step
    sees occupancy-dependent LLC contention (the Fig. 6 effect): working
    sets that fit the LLC re-hit across steps, and each admitted
    co-resident sequence grows the cyclic re-reference distance until
    the shared cache stops covering it.

    The subtraction is exact, not approximate: ``corunner_segments``
    chunks per segment so the prefix's interleaved trace is a prefix of
    the combined interleaved trace, and every counter (LLC hits, DRAM
    row hits, the latency total) is a left-to-right fold over that
    trace.  ``tests/test_sweep.py`` asserts the identity against an
    explicitly warmed reference."""
    mix = mix or MixConfig()
    if warm_prefix is None:
        return interference_lane_metrics(
            segments, llc=llc, dram=dram, mix=mix,
            chunk_bursts=chunk_bursts, t_llc_hit=t_llc_hit)
    full = interference_lane_metrics(
        list(warm_prefix) + list(segments), llc=llc, dram=dram, mix=mix,
        chunk_bursts=chunk_bursts, t_llc_hit=t_llc_hit)
    warm = interference_lane_metrics(
        list(warm_prefix), llc=llc, dram=dram, mix=mix,
        chunk_bursts=chunk_bursts, t_llc_hit=t_llc_hit)
    return _marginal_lane_metrics(full, warm)


def _lane_miss_runs(base, stride, count, llc: LLCConfig, cold: np.ndarray,
                    miss_bits: np.ndarray, *,
                    full_prefix: bool = False) -> tuple:
    """Reconstruct one lane's exact missed-block runs from the vmapped
    kernel's round-scan miss bits plus the analytically-known suffix
    (every block past the round-scanned prefix misses; a cold segment
    is all suffix).  Runs come out in segment order with blocks
    ascending within a segment — the same access order
    ``simulate_segments(collect_miss_runs=True)`` emits, up to
    adjacent-run splits *within* a segment, which the closed-form row
    model is invariant to (identical expanded access sequence).

    ``base/stride/count`` are the lane's (n_segments,) metadata arrays;
    returns ``(first_blocks, n_blocks, seg_idx)`` int64 arrays, fully
    vectorized — no per-segment interpreter work.

    ``full_prefix`` matches a way-masked lane's plan: every segment
    retired entirely in the round scan (the kernel forces
    n_pre == n_blocks for mask != 0 segments), so there is no analytic
    suffix and every miss is a collected bit."""
    bb, sets, ways = llc.block_bytes, llc.sets, llc.ways
    n_seg = base.shape[0]
    live = count > 0
    b_first = base // bb
    b_last = (base + np.maximum(count - 1, 0) * stride) // bb
    nb = np.where(live, b_last - b_first + 1, 0)
    if full_prefix:
        n_pre = nb
    else:
        n_pre = np.where(np.asarray(cold[:n_seg], bool), 0,
                         np.minimum(nb, ways * sets))
    sj, kj, cj = np.nonzero(miss_bits[:n_seg])
    ordv = ((cj.astype(np.int64) - b_first[sj]) % sets
            + kj.astype(np.int64) * sets)
    order = np.lexsort((ordv, sj))
    sj, ordv = sj[order].astype(np.int64), ordv[order]
    first = np.ones(sj.shape[0], bool)
    if sj.shape[0]:
        first[1:] = (sj[1:] != sj[:-1]) | (ordv[1:] != ordv[:-1] + 1)
    pos = np.flatnonzero(first)
    run_seg = sj[pos]
    run_ord = ordv[pos]
    run_len = np.diff(np.append(pos, sj.shape[0]))
    # the analytic suffix is one contiguous run [n_pre, nb) per segment,
    # merged into the last round-scan run when it abuts it
    suf_seg = np.flatnonzero(live & (nb > n_pre))
    suf_len = (nb - n_pre)[suf_seg]
    at = np.searchsorted(run_seg, suf_seg, side="right") - 1
    has_pre = (at >= 0) & (run_seg[np.maximum(at, 0)] == suf_seg)
    at_m = at[has_pre]
    merge = np.zeros(suf_seg.shape[0], bool)
    merge[has_pre] = (run_ord[at_m] + run_len[at_m]) == n_pre[suf_seg[has_pre]]
    run_len[at[merge]] += suf_len[merge]
    run_seg = np.concatenate([run_seg, suf_seg[~merge]])
    run_ord = np.concatenate([run_ord, n_pre[suf_seg[~merge]]])
    run_len = np.concatenate([run_len, suf_len[~merge]])
    order = np.lexsort((run_ord, run_seg))
    run_seg, run_ord, run_len = (a[order] for a in
                                 (run_seg, run_ord, run_len))
    return b_first[run_seg] + run_ord, run_len.astype(np.int64), run_seg


def _mesh_shard_lanes(arrays, mesh):
    """Pad the lane axis to a multiple of the mesh size with count-0
    no-op lanes (geometry repeated so traced scalars stay in range) and
    place every operand lane-sharded, so the jitted vmap runs one lane
    shard per device (computation follows data)."""
    from jax.sharding import NamedSharding, PartitionSpec

    bases, strides, counts, r_needed, cold, sets, ways, blocks = (
        np.asarray(a) for a in arrays)
    n_dev = int(np.prod(list(mesh.shape.values())))
    pad = (-bases.shape[0]) % n_dev

    def rep(a):
        return np.concatenate([a, np.repeat(a[:1], pad, axis=0)])

    def zero(a):
        return np.concatenate(
            [a, np.zeros((pad,) + a.shape[1:], a.dtype)])

    if pad:
        bases, strides = rep(bases), rep(strides)
        counts, r_needed, cold = zero(counts), zero(r_needed), zero(cold)
        sets, ways, blocks = rep(sets), rep(ways), rep(blocks)
    sharding = NamedSharding(mesh, PartitionSpec(mesh.axis_names[0]))
    return [jax.device_put(a, sharding)
            for a in (bases, strides, counts, r_needed, cold,
                      sets, ways, blocks)]


def interference_lane_metrics_batch(nvdla_segs: list, *, llcs, drams,
                                    mixes, chunk_bursts: int = 16,
                                    t_llc_hit: int = 20,
                                    mesh=None,
                                    way_masks=None) -> list[LaneMetrics]:
    """Many interference lanes as vmapped lane programs — the campaign
    executor's data-parallel path (``repro.campaign.executor``).

    ``llcs``/``drams``/``mixes`` are equal-length per-lane config
    sequences; lanes are bucketed by set count (``lane_buckets``) so
    padding waste stays bounded, and each bucket runs as ONE compiled
    program: the geometry-traced segment kernel with miss-bit
    collection (``segment_lane_scan(collect=True)``), vmapped over
    lanes.  Per lane, the host reconstructs the exact missed-block runs
    (``_lane_miss_runs``) and finishes with the same closed-form
    DRAM/latency reduction as the sequential path, so every
    ``LaneMetrics`` is bit-identical to
    ``interference_lane_metrics`` for that lane — the executor
    journals batch results interchangeably with sequential ones.

    ``mesh`` (a 1-D ``jax.sharding.Mesh``, see
    ``repro.launch.mesh.make_sweep_mesh``) shards the lane axis across
    devices; ``mesh=None`` runs the same program on one device.

    Raises ``ValueError`` if any lane's trace falls outside the segment
    engine's support (stride > block_bytes) — callers fall back to the
    sequential path, which expands such segments exactly.

    ``way_masks`` is an equal-length sequence of per-lane LLC way
    partitions (``int`` victim masks, or ``None`` for unpartitioned
    lanes) — masked and unmasked lanes mix freely in one compiled
    batch via the kernel's zero-mask sentinel."""
    lanes_n = len(llcs)
    if not (len(drams) == len(mixes) == lanes_n):
        raise ValueError(
            f"llcs/drams/mixes lengths disagree: {lanes_n}/"
            f"{len(drams)}/{len(mixes)}")
    if way_masks is not None and len(way_masks) != lanes_n:
        raise ValueError(
            f"way_masks length {len(way_masks)} != lanes {lanes_n}")
    if lanes_n == 0:
        return []
    chunks = nvdla_chunks(nvdla_segs, chunk_bursts)
    lanes, nv_masks, lane_sels = [], [], []
    for i, (llc, dram, mix) in enumerate(zip(llcs, drams, mixes)):
        _check_row_block(llc, dram)
        b, s, c, nv = corunner_meta(nvdla_segs, llc=llc, mix=mix,
                                    chunk_bursts=chunk_bursts,
                                    _chunks=chunks)
        lanes.append((b, s, c))
        nv_masks.append(nv)
        wm = way_masks[i] if way_masks is not None else None
        lane_sels.append(None if wm is None
                         else partition_way_sels(nv, llc, wm))
    masked = way_masks is not None
    if masked and mesh is not None:
        raise ValueError("way-masked batches do not support mesh "
                         "sharding yet — pass mesh=None")
    _check_lane_support_meta(lanes, llcs)
    out: list[LaneMetrics | None] = [None] * lanes_n
    for bucket in lane_buckets(llcs):
        cfgs_b = [llcs[i] for i in bucket]
        metas_b = [lanes[i] for i in bucket]
        sets, ways, blocks, max_sets, max_ways = _geometry_arrays(cfgs_b)
        s_pad = max(1, max(m[2].shape[0] for m in metas_b))
        shape = (len(bucket), s_pad)
        bases = np.zeros(shape, np.int32)
        strides = np.ones(shape, np.int32)
        counts = np.zeros(shape, np.int32)
        r_needed = np.zeros(shape, np.int32)
        way_sels = np.zeros(shape, np.int32)
        suffix = "none"
        for row, ((b, s, c), cfg) in enumerate(zip(metas_b, cfgs_b)):
            k = c.shape[0]
            bases[row, :k], strides[row, :k], counts[row, :k] = b, s, c
            bb = cfg.block_bytes
            last = b + np.maximum(c - 1, 0) * s
            nb = np.where(c > 0, last // bb - b // bb + 1, 0)
            sel = lane_sels[bucket[row]]
            if sel is not None:
                # way-partitioned lane: every segment retires entirely
                # in the round scan (no analytic suffix for restricted
                # allocation), so the plan is the full ceil(nb / sets)
                way_sels[row, :k] = sel
                r_needed[row, :k] = (-(-nb // cfg.sets)).astype(np.int32)
                continue
            # per-lane tight plan: enough rounds to retire the
            # min(nb, ways*sets)-block prefix; no cold short-circuit
            # (conservative cold=False is exact either way, and skipping
            # the host-side interval tracker keeps the plan O(numpy))
            r_needed[row, :k] = np.minimum(
                cfg.ways, -(-nb // cfg.sets)).astype(np.int32)
            overflow = nb - np.minimum(nb, cfg.ways * cfg.sets)
            if np.any(overflow > cfg.sets):
                suffix = "full"
            elif suffix == "none" and np.any(overflow > 0):
                suffix = "one"
        cold = np.zeros(shape, bool)
        # the static round-buffer depth only needs to cover this batch's
        # actual plan, not max_ways — chunked interference traces need 1
        r_pad = max(1, int(r_needed.max()))
        arrays = [jnp.asarray(bases), jnp.asarray(strides),
                  jnp.asarray(counts), jnp.asarray(r_needed),
                  jnp.asarray(cold), sets, ways, blocks]
        if mesh is not None:
            arrays = _mesh_shard_lanes(arrays, mesh)
        if masked:
            # the zero-mask sentinel keeps unpartitioned rows on the
            # standard plan inside the same compiled program
            arrays = arrays + [jnp.asarray(way_sels)]
        engine = _lane_engine(max_sets, max_ways, r_pad, True,
                              collect=True, suffix=suffix, masked=masked)
        hits_dev, miss_dev = engine(*arrays)
        hits = np.asarray(hits_dev, np.int64)
        miss_bits = np.asarray(miss_dev)
        for row, i in enumerate(bucket):
            b, s, c = lanes[i]
            n_seg = c.shape[0]
            lane_hits = int(hits[row, :n_seg].sum())
            runs = _lane_miss_runs(b, s, c, llcs[i], cold[row],
                                   miss_bits[row],
                                   full_prefix=lane_sels[i] is not None)
            accesses = int(c.sum())
            run_total = int(runs[1].sum())
            if run_total != accesses - lane_hits:
                raise RuntimeError(
                    "lane miss-run reconstruction disagrees with the "
                    f"kernel: {run_total} missed blocks vs "
                    f"{accesses - lane_hits} misses (lane {i})")
            nv = nv_masks[i]
            out[i] = _lane_metrics_from_runs(
                n_segments=n_seg, accesses=accesses, hits=lane_hits,
                runs=runs, bb=llcs[i].block_bytes, nv=nv,
                dram=drams[i], t_llc_hit=t_llc_hit,
                nv_acc=int(c[nv].sum()),
                nv_hits=int(hits[row, :n_seg][nv].sum()))
    return out


def sweep_interference(*, soc=None, corunners=(0, 1, 2, 3, 4),
                       window_bursts: int = 4096,
                       chunk_bursts: int = 16) -> SweepGrid:
    """Fig. 6, batched: closed-form slowdown curves (``.slowdowns``)
    plus, per (wss, n), the *simulated* NVDLA LLC hit rate with
    co-runner write streams physically interleaved into the trace
    (``.sim_hit_rates``) — every lane a compressed segment stream,
    returned as a typed ``SweepGrid``.  All interference lanes share
    one LLC geometry, so each lane runs one exact segment-engine pass
    that yields per-segment hit attribution *and* the exact LLC-miss
    runs together (the vmapped ``segment_lane_hit_counts`` engine is
    the multi-*geometry* path; replaying here a second time just for
    lane-parallel hit bits would double the simulation cost).  DRAM
    row-hit rates come from the closed-form row model over each lane's
    miss runs (misses of *all* masters mix in the banks, so co-runner
    misses break the NVDLA stream's row locality — the FR-FCFS
    disruption Fig. 6 attributes the "dram" slowdown to)."""
    from repro.core.dram import DRAMConfig
    from repro.core.soc import SoCConfig, interference_sweep as _closed_form

    soc = soc or SoCConfig()
    cf = _closed_form(soc=soc, corunners=corunners)
    llc = soc.mem.llc or LLCConfig()
    dram = soc.mem.dram or DRAMConfig()
    if window_bursts is None:
        # full-frame chunk interleaving explodes to ~2M segments/lane —
        # serially infeasible until segment-count compaction lands (see
        # ROADMAP); refuse loudly rather than run for hours
        raise NotImplementedError(
            "full-frame interference sweeps need RLE segment compaction; "
            "pass a window_bursts cap (the LLC sweep supports full "
            "frames — its lanes stay at stream granularity)")
    nvdla_segs = traces.default_dbb_window(max_bursts=window_bursts)
    # l1-fitting co-runners never reach the shared fabric, so every
    # ('l1', n) lane is the solo-NVDLA trace — simulate it once and fan
    # the result out to all n below
    sim_hit_rates: dict = {}
    sim_row_hit_rates: dict = {}
    for wss, ns in (("l1", (0,)), ("llc", corunners), ("dram", corunners)):
        for n in ns:
            m = interference_lane_metrics(
                nvdla_segs, llc=llc, dram=dram,
                mix=MixConfig(corunners=n, wss=wss),
                chunk_bursts=chunk_bursts)
            keys = ([(wss, n)] if wss != "l1"
                    else [("l1", k) for k in corunners])
            for key in keys:
                sim_hit_rates[key] = m.nvdla_hit_rate
                sim_row_hit_rates[key] = m.nvdla_miss_row_hit_rate
    return SweepGrid(
        kind="interference",
        slowdowns={wss: cf[wss] for wss in ("l1", "llc", "dram")},
        sim_hit_rates=sim_hit_rates,
        sim_row_hit_rates=sim_row_hit_rates,
        window_bursts=window_bursts)
