"""Token-level SoC memory pipeline — the paper's Figure 2, executable.

Composes the exact LLC simulator and the DRAM row/bank model as FAME-1
components behind the NVDLA DBB: each *target* cycle one DBB burst
address flows  DBB -> LLC (hit/miss classification, LRU update) ->
DRAM (row hit/miss service latency for LLC misses).  Host stalls may gate
any component on any host cycle (FireSim's situation when the host FPGA's
DRAM is slow) — the per-access latencies and every cache/bank state are
bit-identical regardless (tests/test_socsim.py, with Hypothesis).

This is the mechanism layer under ``repro.core.accelerator``'s closed-form
timing: where the closed form aggregates streams statistically, this
pipeline replays an actual burst trace cycle by cycle.  Used for (a)
validating the closed form on real layer traces and (b) demonstrating
FAME-1 semantics on the paper's own topology.

Performance: replay rides the chunked early-exit FAME-1 scheduler (the
host-cycle scan stops as soon as the sink drains the trace, and all-stall
host cycles are pre-compacted away — see ``repro.core.fame1``); for
hit-rate-only questions the compressed segment engine in
``repro.core.cache``/``repro.core.traces`` avoids per-access replay, and
for latency *totals* ``simulate_dbb_segments`` composes it with the
closed-form DRAM row model (``repro.core.dram.segment_row_hits``) so the
whole pipeline result comes out of segment-level arithmetic — bit
-identical to the per-access pipeline.  Address arrays go through
``repro.utils.env`` so 64-bit DBB addresses can never be silently
truncated when x64 is disabled.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig
from repro.core.fame1 import Component, FAME1Pipeline
from repro.utils.env import address_dtype, as_address_array


def llc_component(cfg: LLCConfig) -> Component:
    sets, ways = cfg.sets, cfg.ways
    adt = address_dtype()

    def step(state, addr):
        tags, age = state
        block = addr // cfg.block_bytes
        s = (block % sets).astype(jnp.int32)
        t = block // sets
        row_tags = tags[s]
        row_age = age[s]
        match = row_tags == t
        hit = jnp.any(match)
        way = jnp.where(hit, jnp.argmax(match), jnp.argmax(row_age))
        tags = tags.at[s, way].set(t)
        age = age.at[s].set(jnp.where(jnp.arange(ways) == way, 0,
                                      row_age + 1))
        return (tags, age), {"addr": addr, "hit": hit}

    init = (jnp.full((sets, ways), -1, adt),
            jnp.zeros((sets, ways), jnp.int32))
    return Component("llc", step, init,
                     {"addr": jnp.zeros((), adt), "hit": jnp.bool_(False)})


def dram_component(llc_cfg: LLCConfig, dram_cfg: DRAMConfig,
                   t_llc_hit: int = 20) -> Component:
    banks = dram_cfg.banks

    def step(open_rows, tok):
        addr, hit = tok["addr"], tok["hit"]
        row = addr // dram_cfg.row_bytes
        bank = (row % banks).astype(jnp.int32)
        row_of_bank = row // banks
        row_hit = open_rows[bank] == row_of_bank
        dram_lat = jnp.where(
            row_hit, dram_cfg.t_cas_cycles,
            dram_cfg.t_rp_cycles + dram_cfg.t_rcd_cycles
            + dram_cfg.t_cas_cycles)
        # a miss pays the LLC lookup AND the DRAM access
        lat = jnp.where(hit, t_llc_hit, t_llc_hit + dram_lat).astype(jnp.int32)
        # only LLC misses touch DRAM state (no row activation on a hit)
        open_rows = jnp.where(
            hit, open_rows, open_rows.at[bank].set(row_of_bank))
        return open_rows, lat

    return Component("dram", step, jnp.full((banks,), -1, address_dtype()),
                     jnp.int32(0))


@dataclasses.dataclass
class MemPipelineResult:
    latencies: jax.Array     # (T,) per-access service latency
    total_cycles: jax.Array  # sum
    host_cycles: int | None = None   # host cycles the scheduler spent


@functools.lru_cache(maxsize=32)
def _mem_pipeline(llc_cfg: LLCConfig, dram_cfg: DRAMConfig,
                  x64: bool) -> FAME1Pipeline:
    """One pipeline (and so one jit cache) per memory configuration —
    repeated replays reuse the compiled host program.  The x64 key
    rebuilds the pipeline if the precision mode flips mid-process."""
    return FAME1Pipeline([llc_component(llc_cfg),
                          dram_component(llc_cfg, dram_cfg)])


def _positional_config_warning(fn_name: str) -> str:
    return (f"positional configs to {fn_name}() are deprecated; pass "
            "llc=/dram= keyword-only (the shared convention across the "
            "sweep/pipeline APIs)")


def _legacy_configs(fn_name: str, legacy: tuple, llc, dram):
    """One-release escape hatch: positional (llc, dram) still works but
    warns.  The ``DeprecationWarning`` itself is emitted by the *public*
    function (``warnings.warn(..., stacklevel=2)``, the repo-wide
    convention — every deprecation attributes to the caller's line, and
    tests/test_deprecations.py asserts the attribution for every site).
    This helper used to warn on the public function's behalf, which
    forced a one-off ``stacklevel=3`` to skip its own frame.  Returns
    the resolved (llc, dram); raises ``TypeError`` on a config passed
    both ways or a missing ``llc``."""
    if legacy:
        if len(legacy) > 2:
            raise TypeError(f"{fn_name}() takes at most 2 positional "
                            f"configs, got {len(legacy)}")
        if llc is not None or (dram is not None and len(legacy) > 1):
            raise TypeError(f"{fn_name}() got a config both positionally "
                            "and by keyword")
        llc = legacy[0]
        if len(legacy) > 1:
            dram = legacy[1]
    if llc is None:
        raise TypeError(f"{fn_name}() missing required keyword argument "
                        "'llc'")
    return llc, dram


def simulate_dbb_stream(byte_addrs, *legacy, llc: LLCConfig | None = None,
                        dram: DRAMConfig | None = None,
                        host_stalls=None,
                        early_exit: bool = True) -> MemPipelineResult:
    """Replay a DBB burst-address trace through the LLC -> DRAM pipeline.

    Configs are keyword-only (``llc=``, ``dram=``) — the shared
    convention across the sweep/pipeline APIs; positional configs still
    work for one release but emit ``DeprecationWarning``.
    ``early_exit=False`` forces the seed's fixed-length host schedule
    (used by benchmarks as the before/after baseline); results are
    bit-identical either way.
    """
    from repro.utils.env import x64_enabled

    if legacy:
        warnings.warn(_positional_config_warning("simulate_dbb_stream"),
                      DeprecationWarning, stacklevel=2)
    llc, dram = _legacy_configs("simulate_dbb_stream", legacy, llc, dram)
    dram = dram or DRAMConfig()
    addrs = as_address_array(byte_addrs, what="DBB byte address")
    pipe = _mem_pipeline(llc, dram, x64_enabled())
    _, lats, n = pipe.run(addrs, host_stalls=host_stalls,
                          max_host_cycles=(host_stalls.shape[0]
                                           if host_stalls is not None else None),
                          early_exit=early_exit)
    t = addrs.shape[0]
    return MemPipelineResult(latencies=lats[:t],
                             total_cycles=jnp.sum(lats[:t]),
                             host_cycles=pipe.last_host_cycles)


# --------------------------------------------------------------------------
# segment-native totals: no per-access replay at all
# --------------------------------------------------------------------------
class PipelineInvariantError(ValueError):
    """A memory-pipeline result violates a closed-form invariant — the
    numbers cannot have come from a correct simulation (a poisoned
    worker, a corrupted record, an injected fault)."""


def check_segment_totals(*, accesses: int, llc_hits: int,
                         dram_row_hits: int, total_cycles: int,
                         dram: DRAMConfig, t_llc_hit: int = 20) -> None:
    """Validate a (accesses, hits, row hits, total) quadruple against
    the closed-form latency identity of ``simulate_dbb_segments``:

        total = T*t_llc_hit + misses*tCAS + row_misses*(tRP + tRCD)

    plus the counting invariants 0 <= hits <= accesses and
    0 <= row_hits <= misses.  Raises ``PipelineInvariantError`` with the
    failing relation spelled out; used both on fresh results and when a
    resumed campaign re-validates journaled records
    (``repro.campaign.executor``)."""
    vals = (accesses, llc_hits, dram_row_hits, total_cycles)
    if not all(isinstance(v, int) for v in vals):
        raise PipelineInvariantError(
            f"pipeline counters must be ints, got {vals!r}")
    if accesses < 0 or llc_hits < 0 or dram_row_hits < 0:
        raise PipelineInvariantError(
            f"negative pipeline counter: accesses={accesses} "
            f"llc_hits={llc_hits} dram_row_hits={dram_row_hits}")
    if llc_hits > accesses:
        raise PipelineInvariantError(
            f"llc_hits {llc_hits} exceeds accesses {accesses}")
    misses = accesses - llc_hits
    if dram_row_hits > misses:
        raise PipelineInvariantError(
            f"dram_row_hits {dram_row_hits} exceeds LLC misses {misses}")
    expect = (accesses * t_llc_hit + misses * dram.t_cas_cycles
              + (misses - dram_row_hits)
              * (dram.t_rp_cycles + dram.t_rcd_cycles))
    if total_cycles != expect:
        raise PipelineInvariantError(
            f"total_cycles {total_cycles} != closed form {expect} "
            f"(accesses={accesses} misses={misses} "
            f"row_hits={dram_row_hits})")


def check_segment_totals_batch(*, accesses, llc_hits, dram_row_hits,
                               total_cycles, drams,
                               t_llc_hit: int = 20) -> None:
    """Vectorized ``check_segment_totals`` over a point batch — the
    executor's fast pre-validation of an unstacked mesh batch before
    the per-point guardrails run.  All four counter arguments are
    equal-length sequences of ints, ``drams`` the per-point DRAM
    configs.  Raises ``PipelineInvariantError`` naming every failing
    batch index (one bad point must not mask another — the caller
    quarantines per point)."""
    import numpy as np

    acc = np.asarray(accesses, np.int64)
    hits = np.asarray(llc_hits, np.int64)
    row = np.asarray(dram_row_hits, np.int64)
    tot = np.asarray(total_cycles, np.int64)
    n = len(acc)
    if not (len(hits) == len(row) == len(tot) == len(drams) == n):
        raise PipelineInvariantError(
            "batch counter sequences have mismatched lengths")
    misses = acc - hits
    t_cas = np.asarray([d.t_cas_cycles for d in drams], np.int64)
    t_act = np.asarray([d.t_rp_cycles + d.t_rcd_cycles for d in drams],
                       np.int64)
    expect = acc * t_llc_hit + misses * t_cas + (misses - row) * t_act
    bad = ((acc < 0) | (hits < 0) | (row < 0) | (hits > acc)
           | (row > misses) | (tot != expect))
    if bad.any():
        idxs = np.nonzero(bad)[0]
        details = ", ".join(
            f"[{i}] accesses={acc[i]} llc_hits={hits[i]} "
            f"row_hits={row[i]} total={tot[i]} expect={expect[i]}"
            for i in idxs[:8])
        raise PipelineInvariantError(
            f"{idxs.size}/{n} batch points violate the pipeline "
            f"invariants: {details}")


@dataclasses.dataclass
class SegmentPipelineResult:
    total_cycles: int            # == simulate_dbb_stream(...).total_cycles
    accesses: int
    llc_hits: int
    dram_row_hits: int           # row hits among the LLC misses

    @property
    def llc_hit_rate(self) -> float:
        return self.llc_hits / max(1, self.accesses)

    @property
    def mean_latency(self) -> float:
        return self.total_cycles / max(1, self.accesses)

    def check_invariants(self, dram: DRAMConfig,
                         t_llc_hit: int = 20) -> "SegmentPipelineResult":
        """Raise ``PipelineInvariantError`` unless the counters satisfy
        the closed-form identities; returns self for chaining."""
        check_segment_totals(
            accesses=self.accesses, llc_hits=self.llc_hits,
            dram_row_hits=self.dram_row_hits,
            total_cycles=self.total_cycles,
            dram=dram, t_llc_hit=t_llc_hit)
        return self


def simulate_dbb_segments(segments, *legacy, llc: LLCConfig | None = None,
                          dram: DRAMConfig | None = None,
                          t_llc_hit: int = 20) -> SegmentPipelineResult:
    """Latency totals of the LLC -> DRAM pipeline over a *compressed*
    DBB trace, with no per-access replay on either side.

    The segment LLC engine classifies hits and emits the exact miss
    stream as runs of consecutive blocks; the closed-form DRAM row model
    counts row hits over those runs with per-bank open-row carry.  Since
    every per-access latency is determined by (llc hit?, dram row hit?),
    the totals are bit-identical to ``simulate_dbb_stream`` on the
    expanded trace (tests/test_socsim.py):

        total = T*t_llc_hit + misses*tCAS + row_misses*(tRP + tRCD)

    Requires ``dram.row_bytes % llc.block_bytes == 0`` (every standard
    geometry) so a missed block's row is independent of which burst in
    the block missed.  Configs are keyword-only (``llc=``, ``dram=``);
    positional use warns for one release.
    """
    from repro.core.cache import simulate_segments
    from repro.core.dram import segment_row_hits

    if legacy:
        warnings.warn(_positional_config_warning("simulate_dbb_segments"),
                      DeprecationWarning, stacklevel=2)
    llc, dram = _legacy_configs("simulate_dbb_segments", legacy, llc, dram)
    dram = dram or DRAMConfig()
    bb = llc.block_bytes
    if dram.row_bytes % bb:
        raise ValueError(
            f"row_bytes {dram.row_bytes} not a multiple of block_bytes "
            f"{bb}: a block could straddle rows; use simulate_dbb_stream")
    res = simulate_segments(segments, llc, collect_miss_runs=True)
    row = segment_row_hits([(b * bb, bb, c) for b, c, _ in res.miss_runs],
                           dram)
    misses = res.accesses - res.hits
    row_misses = misses - row.row_hits
    total = (res.accesses * t_llc_hit
             + misses * dram.t_cas_cycles
             + row_misses * (dram.t_rp_cycles + dram.t_rcd_cycles))
    return SegmentPipelineResult(total_cycles=int(total),
                                 accesses=res.accesses,
                                 llc_hits=res.hits,
                                 dram_row_hits=row.row_hits)
