"""Cycle-token NoC switch: FireSim's token-routed switch model in JAX.

FireSim simulates distributed targets by exchanging *tokens* — one per
target cycle per link — through a software switch
(``target-design/switch/switch.cc``): each port has ingress/egress
queues, links have a fixed latency in target cycles, and the switch
arbitrates deterministically, so an N-node simulation is cycle-exact
and bit-reproducible regardless of host scheduling.  This module is
that switch for the paper's SoC farm (``repro.core.farm``): N nodes'
DBB request flits contend for a shared memory port, and the per-flit
latency distribution is the interconnect half of the tail-latency
story (the LLC/DRAM half comes from the segment engine).

Model, per target cycle (identical in both implementations):

1. **inject** — ``dests[c, p] >= 0`` appends a flit ``(inject=c,
   dst=dests[c, p])`` to ingress FIFO ``p``.  A full FIFO sets the
   overflow flag (the driver raises; the default depth provably cannot
   overflow).
2. **arbitrate** — each egress port grants among the *cycle-start*
   ingress FIFO heads whose flit has traversed the input link
   (``inject + link_latency <= c``) and targets it, picking the first
   in round-robin order from its pointer; every egress moves at most
   one flit per cycle (the bandwidth token).  Heads are snapshotted
   before any pop, and an ingress head targets exactly one egress, so
   simultaneous grants never conflict.
3. **deliver** — a granted flit pops, records latency ``c - inject``
   (queueing + link), and advances its egress's round-robin pointer
   past the granted ingress.

Two implementations, proven bit-identical for every bundle size
(tests/test_noc.py, the acceptance parity bar):

* ``simulate_reference`` — a plain-Python per-cycle loop, the
  semantics oracle;
* ``NoCSwitch.simulate`` — the same cycle function as a JAX scan body,
  executed in FAME-1 *token bundles* of ``bundle_cycles`` target cycles
  per host step via ``fame1.chunked_scan`` (one fused device program,
  early-exiting the host loop once every flit has delivered).  Bundle
  padding cycles are clock-gated no-ops, so results are invariant to
  the bundle size — including bundles that do not divide the cycle
  count.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fame1 import chunked_scan


@dataclasses.dataclass(frozen=True)
class NoCConfig:
    """Switch geometry and link timing, all in target cycles.

    ``queue_depth=None`` sizes every ingress FIFO to the schedule's
    per-port flit total — deep enough that overflow is impossible, the
    FireSim switch's "infinite input buffer" configuration.  A concrete
    depth models finite buffering: the simulation then reports overflow
    instead of silently dropping flits."""
    ports: int = 5
    link_latency: int = 4
    queue_depth: int | None = None

    def __post_init__(self):
        if self.ports < 1:
            raise ValueError(f"ports must be >= 1, got {self.ports}")
        if self.link_latency < 0:
            raise ValueError("link_latency must be >= 0, got "
                             f"{self.link_latency}")
        if self.queue_depth is not None and self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1 (or None), got "
                             f"{self.queue_depth}")


@dataclasses.dataclass(frozen=True)
class NoCResult:
    """Flattened delivery log, one row per delivered flit in
    (deliver_cycle, egress) order — deterministic, so two simulations
    agree iff their arrays are element-wise equal."""
    deliver_cycle: np.ndarray    # (F,) int64
    egress: np.ndarray          # (F,) int64 egress port
    src: np.ndarray             # (F,) int64 ingress port
    latency: np.ndarray         # (F,) int64  deliver - inject
    cycles_run: int             # target cycles actually simulated
    host_steps: int | None = None   # bundles executed (None: reference)

    @property
    def inject_cycle(self) -> np.ndarray:
        return self.deliver_cycle - self.latency

    def source_latencies(self, port: int) -> np.ndarray:
        """Latencies of port ``port``'s flits in injection order (a
        single-egress source delivers in FIFO order, so deliver order
        == inject order — the farm driver's per-request view)."""
        mine = self.src == port
        order = np.argsort(self.inject_cycle[mine], kind="stable")
        return self.latency[mine][order]


class NoCOverflowError(RuntimeError):
    """An ingress FIFO exceeded ``queue_depth`` — finite buffering
    dropped a flit, so latencies past that point are meaningless."""


def _schedule_params(dests: np.ndarray, cfg: NoCConfig
                     ) -> tuple[int, int, int]:
    """(total_flits, horizon, depth) for an injection schedule.  The
    horizon bounds the drain time: every egress moves >= 1 eligible
    flit per cycle, so all F flits deliver within
    T + F + link_latency cycles of the last injection opportunity."""
    if dests.ndim != 2 or dests.shape[1] != cfg.ports:
        raise ValueError(f"dests must be (T, {cfg.ports}), got "
                         f"{dests.shape}")
    if np.any(dests >= cfg.ports):
        raise ValueError("dests entries must be < ports (or negative "
                         "for no-flit cycles)")
    total = int((dests >= 0).sum())
    horizon = dests.shape[0] + total + cfg.link_latency + 1
    depth = (cfg.queue_depth if cfg.queue_depth is not None
             else max(1, int((dests >= 0).sum(axis=0).max(initial=1))))
    return total, horizon, depth


def simulate_reference(dests, cfg: NoCConfig) -> NoCResult:
    """The per-cycle reference scheduler: one plain-Python iteration
    per target cycle, no batching — the oracle the token-bundle
    implementation must match bit for bit."""
    dests = np.asarray(dests, np.int64)
    total, horizon, depth = _schedule_params(dests, cfg)
    ports, link = cfg.ports, cfg.link_latency
    queues: list[list[tuple[int, int]]] = [[] for _ in range(ports)]
    rr = [0] * ports
    rows: list[tuple[int, int, int, int]] = []
    delivered = 0
    c = 0
    while delivered < total and c < horizon:
        if c < dests.shape[0]:
            for p in range(ports):
                d = int(dests[c, p])
                if d >= 0:
                    if len(queues[p]) >= depth:
                        raise NoCOverflowError(
                            f"ingress FIFO {p} overflowed depth {depth} "
                            f"at cycle {c}")
                    queues[p].append((c, d))
        # arbitrate against the cycle-start head snapshot, then pop
        grants: list[tuple[int, int]] = []
        for e in range(ports):
            for k in range(ports):
                p = (rr[e] + k) % ports
                q = queues[p]
                if q and q[0][1] == e and q[0][0] + link <= c:
                    grants.append((e, p))
                    break
        for e, p in grants:
            inj, _ = queues[p].pop(0)
            rows.append((c, e, p, c - inj))
            rr[e] = (p + 1) % ports
            delivered += 1
        c += 1
    arr = np.asarray(rows, np.int64).reshape(-1, 4)
    return NoCResult(deliver_cycle=arr[:, 0], egress=arr[:, 1],
                     src=arr[:, 2], latency=arr[:, 3], cycles_run=c)


@functools.lru_cache(maxsize=16)
def _switch_program(ports: int, link: int, depth: int, h_pad: int,
                    bundle: int):
    """One jitted token-bundle program per (geometry, padded horizon,
    bundle size) — repeated farms at the same shape reuse the compile."""
    p_idx = jnp.arange(ports, dtype=jnp.int32)

    def cycle(carry, x, active):
        ts_buf, dst_buf, head, size, rr, delivered, target, ovf = carry
        dst_row, cyc = x
        # inject: append this cycle's flits to the ingress FIFOs
        has = active & (dst_row >= 0)
        can = has & (size < depth)
        pos = (head + size) % depth
        ts_buf = ts_buf.at[p_idx, pos].set(
            jnp.where(can, cyc, ts_buf[p_idx, pos]))
        dst_buf = dst_buf.at[p_idx, pos].set(
            jnp.where(can, dst_row, dst_buf[p_idx, pos]))
        ovf = ovf | jnp.any(has & ~can)
        size = size + can.astype(jnp.int32)
        # arbitrate: cycle-start heads, round-robin per egress
        h_ts = ts_buf[p_idx, head]
        h_dst = dst_buf[p_idx, head]
        elig = active & (size > 0) & (h_ts + link <= cyc)
        cand = elig[None, :] & (h_dst[None, :] == p_idx[:, None])
        key = jnp.where(cand, (p_idx[None, :] - rr[:, None]) % ports,
                        ports)
        granted = jnp.min(key, axis=1) < ports
        sel = jnp.argmin(key, axis=1).astype(jnp.int32)
        # deliver: pop winners (an ingress head targets exactly one
        # egress, so grants never collide on a port)
        pop = jnp.any(granted[:, None]
                      & (p_idx[None, :] == sel[:, None]), axis=0)
        lat = jnp.where(granted, cyc - h_ts[sel], 0)
        src = jnp.where(granted, sel, -1)
        head = (head + pop.astype(jnp.int32)) % depth
        size = size - pop.astype(jnp.int32)
        rr = jnp.where(granted, (sel + 1) % ports, rr)
        delivered = delivered + jnp.sum(granted, dtype=jnp.int32)
        carry = (ts_buf, dst_buf, head, size, rr, delivered, target, ovf)
        return carry, (granted, src, lat)

    @jax.jit
    def prog(dests_pad, total):
        init = (jnp.zeros((ports, depth), jnp.int32),
                jnp.full((ports, depth), -1, jnp.int32),
                jnp.zeros((ports,), jnp.int32),
                jnp.zeros((ports,), jnp.int32),
                jnp.zeros((ports,), jnp.int32),
                jnp.int32(0), jnp.int32(total), jnp.bool_(False))
        carry, ys, bundles = chunked_scan(
            cycle, init,
            (dests_pad, jnp.arange(h_pad, dtype=jnp.int32)),
            cont_fn=lambda c: c[5] < c[6], chunk_len=bundle)
        _, _, _, _, _, delivered, _, ovf = carry
        return ys, delivered, ovf, bundles

    return prog


class NoCSwitch:
    """The token-bundle switch: ``simulate`` runs the whole farm's
    injection schedule as one fused device program, k target cycles
    per host step."""

    def __init__(self, cfg: NoCConfig | None = None):
        self.cfg = cfg or NoCConfig()

    def simulate(self, dests, *, bundle_cycles: int = 64) -> NoCResult:
        """``dests`` (T, ports) int: entry (c, p) is the egress port of
        the flit port p injects at cycle c, or -1 for none.  Returns
        the delivery log; raises ``NoCOverflowError`` if a finite
        ``queue_depth`` dropped a flit."""
        dests = np.asarray(dests, np.int64)
        total, horizon, depth = _schedule_params(dests, self.cfg)
        # bucket the horizon to a power of two (padding rows inject
        # nothing) so similar-length schedules share one compile
        h_pad = 1 << max(0, horizon - 1).bit_length()
        sched = np.full((h_pad, self.cfg.ports), -1, np.int32)
        sched[:dests.shape[0]] = dests
        prog = _switch_program(self.cfg.ports, self.cfg.link_latency,
                               depth, h_pad, int(bundle_cycles))
        (granted, src, lat), delivered, ovf, bundles = prog(
            jnp.asarray(sched), total)
        if bool(ovf):
            raise NoCOverflowError(
                f"an ingress FIFO overflowed depth {depth}; deepen "
                "queue_depth or thin the injection schedule")
        granted = np.asarray(granted)
        cyc_i, egr_i = np.nonzero(granted)         # row-major: cycle-major
        if int(delivered) != total:
            raise RuntimeError(
                f"switch delivered {int(delivered)}/{total} flits within "
                f"the {h_pad}-cycle horizon — scheduler invariant broken")
        return NoCResult(
            deliver_cycle=cyc_i.astype(np.int64),
            egress=egr_i.astype(np.int64),
            src=np.asarray(src)[cyc_i, egr_i].astype(np.int64),
            latency=np.asarray(lat)[cyc_i, egr_i].astype(np.int64),
            cycles_run=int(min(int(bundles) * int(bundle_cycles), h_pad)),
            host_steps=int(bundles))
