"""DRAM timing model: banks, open rows, FR-FCFS-style row-hit priority.

Matches the FireSim memory-model knobs the paper uses (DDR3, 4 ranks x 8
banks, FR-FCFS): per access the latency is

    row hit   -> tCAS
    row miss  -> tRP + tRCD + tCAS        (precharge + activate + CAS)

simulated exactly with a ``lax.scan`` carrying the open row per bank —
or, for stride-run segment streams (the compressed DBB traces of
``repro.core.traces`` and the LLC miss runs the segment engine emits),
computed in closed form by ``segment_row_hits``: rows touched per
segment, per-bank open-row carry across segment boundaries, bit
-identical to the per-access scan with O(segments * banks) work.
FR-FCFS's *scheduling* effect (row hits served first under load) and
inter-master contention are modeled at the queue level in
``repro.core.interference`` — this module is the deterministic service
-time component.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DRAMConfig:
    banks: int = 32                  # 4 ranks x 8 banks
    row_bytes: int = 2048
    t_cas_cycles: int = 14           # DDR3-1600-ish, in memory-clock cycles
    t_rcd_cycles: int = 14
    t_rp_cycles: int = 14
    clock_hz: float = 800e6          # memory controller clock
    bus_bytes_per_cycle: int = 16    # 64-bit DDR -> 16 B / controller cycle

    @property
    def peak_bw(self) -> float:
        return self.clock_hz * self.bus_bytes_per_cycle


@functools.partial(jax.jit, static_argnames=("banks",))
def access_latencies(byte_addrs: jax.Array, *, banks: int, row_bytes: int,
                     t_cas: int, t_rcd: int, t_rp: int):
    """byte_addrs (T,) -> per-access latency in memory cycles (exact
    open-row bookkeeping; no queueing)."""
    row = byte_addrs // row_bytes
    bank = row % banks
    row_of_bank = row // banks

    def step(open_rows, inp):
        b, r = inp
        hit = open_rows[b] == r
        lat = jnp.where(hit, t_cas, t_rp + t_rcd + t_cas)
        return open_rows.at[b].set(r), lat

    init = jnp.full((banks,), -1, jnp.int64)
    _, lats = jax.lax.scan(step, init,
                           (bank.astype(jnp.int32), row_of_bank))
    return lats


def row_hit_rate(byte_addrs, cfg: DRAMConfig) -> float:
    lats = access_latencies(
        jnp.asarray(byte_addrs, jnp.int64), banks=cfg.banks,
        row_bytes=cfg.row_bytes, t_cas=cfg.t_cas_cycles,
        t_rcd=cfg.t_rcd_cycles, t_rp=cfg.t_rp_cycles)
    return float(jnp.mean((lats == cfg.t_cas_cycles).astype(jnp.float32)))


# --------------------------------------------------------------------------
# closed-form row model for stride-run segments
# --------------------------------------------------------------------------
@dataclasses.dataclass
class RowHitResult:
    row_hits: int                # accesses served from an open row
    accesses: int
    open_rows: np.ndarray        # final per-bank open row ids (-1 closed)
    per_segment: np.ndarray      # (n_segments,) int64 row hits

    @property
    def row_hit_rate(self) -> float:
        return self.row_hits / max(1, self.accesses)


def _bank_first_last_rows(r0: int, r1: int, banks: int):
    """For the contiguous row run [r0, r1]: each bank's first and last
    visited row (full row ids), and which banks are visited at all."""
    b = np.arange(banks, dtype=np.int64)
    first = r0 + ((b - r0) % banks)
    last = r1 - ((r1 - b) % banks)
    visited = first <= r1
    return first, last, visited


def _row_hits_bulk(base: np.ndarray, stride: np.ndarray, count: np.ndarray,
                   banks: int, rb: int, rows_state: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized carry chain for stride <= row_bytes segments: the
    per-bank open-row state a segment observes is the ``last`` row of
    the most recent earlier segment that visited the bank (exclusive
    running maximum over visit indices), so the whole serial loop
    collapses to O(segments * banks) numpy with no Python per segment.
    Returns (per_segment row hits, final open rows) — bit-identical to
    the scalar loop."""
    n = base.shape[0]
    if n == 0:
        return np.zeros(0, np.int64), rows_state[:banks].copy()
    live = count > 0
    r0 = base // rb
    r1 = (base + np.maximum(count - 1, 0) * stride) // rb
    b = np.arange(banks, dtype=np.int64)[None, :]
    first = r0[:, None] + ((b - r0[:, None]) % banks)
    last = r1[:, None] - ((r1[:, None] - b) % banks)
    visited = (first <= r1[:, None]) & live[:, None]
    idx = np.where(visited, np.arange(n, dtype=np.int64)[:, None], -1)
    latest = np.maximum.accumulate(idx, axis=0)
    prev = np.vstack([np.full((1, banks), -1, np.int64), latest[:-1]])
    prev_last = np.where(
        prev >= 0,
        np.take_along_axis(last, np.maximum(prev, 0), axis=0),
        rows_state[None, :banks])
    carry = (visited & (prev_last == first)).sum(axis=1)
    per_seg = np.where(live, count - (r1 - r0 + 1) + carry, 0)
    final = np.where(
        latest[-1] >= 0,
        np.take_along_axis(last, np.maximum(latest[-1:], 0), axis=0)[0],
        rows_state[:banks])
    return per_seg.astype(np.int64), final.astype(np.int64)


def segment_row_hits(segments, cfg: DRAMConfig,
                     open_rows: np.ndarray | None = None) -> RowHitResult:
    """Row-hit count of a compressed stride-run trace, closed form.

    Bit-identical to replaying the expanded trace through
    ``access_latencies`` (tests/test_dram_segments.py, with Hypothesis),
    with serial work O(segments * banks) instead of O(accesses):

    * a segment with stride <= row_bytes sweeps the contiguous row run
      [base//row_bytes, last//row_bytes]; every row is visited once,
      contiguously, so all accesses beyond each row's first hit that
      open row, and a row's *first* access can only hit via the open-row
      state carried in from earlier segments — possible only for each
      bank's first visited row (later visits to a bank always follow an
      intra-segment activation of a different row of that bank);
    * a segment with stride > row_bytes touches a strictly increasing,
      gappy row sequence — rare (never produced by DBB streams or LLC
      miss runs), replayed per access with the same open-row carry.

    ``open_rows`` continues from a prior result's state (full row ids,
    -1 = closed); segments may be ``Segment`` objects or
    ``(base, stride, count)`` tuples, base/stride in bytes.
    """
    from repro.core.traces import segment_tuple

    banks, rb = cfg.banks, cfg.row_bytes
    rows_state = (np.full(banks, -1, np.int64) if open_rows is None
                  else np.array(open_rows, np.int64, copy=True))
    if isinstance(segments, tuple) and len(segments) == 3 \
            and isinstance(segments[0], np.ndarray):
        base_a, stride_a, count_a = (np.asarray(a, np.int64)
                                     for a in segments)
    else:
        seg_list = [segment_tuple(s) for s in segments]
        base_a = np.asarray([m[0] for m in seg_list], np.int64)
        stride_a = np.asarray([m[1] for m in seg_list], np.int64)
        count_a = np.asarray([m[2] for m in seg_list], np.int64)
    live_a = count_a > 0
    if np.any(live_a & (stride_a <= 0)):
        bad = int(stride_a[live_a & (stride_a <= 0)][0])
        raise ValueError(f"segment stride must be positive: {bad}")
    if not np.any(live_a & (stride_a > rb)):
        per_seg, rows_state = _row_hits_bulk(
            base_a, stride_a, count_a, banks, rb, rows_state)
        return RowHitResult(row_hits=int(per_seg.sum()),
                            accesses=int(count_a[live_a].sum()),
                            open_rows=rows_state, per_segment=per_seg)
    seg_list = list(zip(base_a.tolist(), stride_a.tolist(),
                        count_a.tolist()))
    per_seg = np.zeros(len(seg_list), np.int64)
    accesses = 0
    for i, (base, stride, count) in enumerate(seg_list):
        if count <= 0:
            continue
        if stride <= 0:
            raise ValueError(f"segment stride must be positive: {stride}")
        accesses += count
        if stride > rb:
            # gappy rows: every access opens (or re-hits) its own row
            rows = (base + np.arange(count, dtype=np.int64) * stride) // rb
            hits = 0
            for r in rows:
                b = int(r % banks)
                hits += rows_state[b] == r
                rows_state[b] = r
            per_seg[i] = hits
            continue
        r0 = base // rb
        r1 = (base + (count - 1) * stride) // rb
        first, last, visited = _bank_first_last_rows(r0, r1, banks)
        carry_hits = int((visited & (rows_state[:banks] == first)).sum())
        per_seg[i] = count - (r1 - r0 + 1) + carry_hits
        rows_state = np.where(visited, last, rows_state)
    return RowHitResult(row_hits=int(per_seg.sum()), accesses=accesses,
                        open_rows=rows_state, per_segment=per_seg)
