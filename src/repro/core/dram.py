"""DRAM timing model: banks, open rows, FR-FCFS-style row-hit priority.

Matches the FireSim memory-model knobs the paper uses (DDR3, 4 ranks x 8
banks, FR-FCFS): per access the latency is

    row hit   -> tCAS
    row miss  -> tRP + tRCD + tCAS        (precharge + activate + CAS)

simulated exactly with a ``lax.scan`` carrying the open row per bank.
FR-FCFS's *scheduling* effect (row hits served first under load) and
inter-master contention are modeled at the queue level in
``repro.core.interference`` — this module is the deterministic service
-time component.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DRAMConfig:
    banks: int = 32                  # 4 ranks x 8 banks
    row_bytes: int = 2048
    t_cas_cycles: int = 14           # DDR3-1600-ish, in memory-clock cycles
    t_rcd_cycles: int = 14
    t_rp_cycles: int = 14
    clock_hz: float = 800e6          # memory controller clock
    bus_bytes_per_cycle: int = 16    # 64-bit DDR -> 16 B / controller cycle

    @property
    def peak_bw(self) -> float:
        return self.clock_hz * self.bus_bytes_per_cycle


@functools.partial(jax.jit, static_argnames=("banks",))
def access_latencies(byte_addrs: jax.Array, *, banks: int, row_bytes: int,
                     t_cas: int, t_rcd: int, t_rp: int):
    """byte_addrs (T,) -> per-access latency in memory cycles (exact
    open-row bookkeeping; no queueing)."""
    row = byte_addrs // row_bytes
    bank = row % banks
    row_of_bank = row // banks

    def step(open_rows, inp):
        b, r = inp
        hit = open_rows[b] == r
        lat = jnp.where(hit, t_cas, t_rp + t_rcd + t_cas)
        return open_rows.at[b].set(r), lat

    init = jnp.full((banks,), -1, jnp.int64)
    _, lats = jax.lax.scan(step, init,
                           (bank.astype(jnp.int32), row_of_bank))
    return lats


def row_hit_rate(byte_addrs, cfg: DRAMConfig) -> float:
    lats = access_latencies(
        jnp.asarray(byte_addrs, jnp.int64), banks=cfg.banks,
        row_bytes=cfg.row_bytes, t_cas=cfg.t_cas_cycles,
        t_rcd=cfg.t_rcd_cycles, t_rp=cfg.t_rp_cycles)
    return float(jnp.mean((lats == cfg.t_cas_cycles).astype(jnp.float32)))
