"""Systolic-array NPU model: the second accelerator backend.

The paper evaluates one accelerator (NVDLA) behind the shared LLC +
DRAM; this module adds an architecturally different second point — a
parameterized weight-stationary systolic GEMM array (rows x cols PEs,
explicit input/weight/accumulator SRAMs) — to prove the segment stack
is accelerator-agnostic.  The NPU's command stream is a list of
``GemmOp``s from the repo's own model zoo (transformer/mamba2 decode
projections, the whisper encoder, YOLOv3 conv-as-GEMM via im2col), and
it compiles to exactly the same currency NVDLA traces use: compressed
``(base, stride, count)`` DBB segments (``repro.core.traces.Segment``)
that replay through ``core.cache`` / ``core.dram`` / ``core.socsim``
and the vmapped sweep lanes *unchanged*.

Dataflow (weight-stationary):

* a weight tile of ``rows x cols`` elements is held in the PE grid
  (rows = the K/reduction dim, cols = the N/output dim); input rows
  stream through, one M row per cycle once the pipeline fills;
* the K dimension is tiled by ``rows``, N by ``cols``; the M dimension
  is tiled so the streamed input tile fits the input SRAM and the
  partial sums fit the accumulator SRAM
  (``m_tile = min(ifm_buf/(rows*elem), acc_buf/(cols*acc))``);
* per (n, m) tile visit the k loop runs innermost, so the weight
  k-stripe and the input k-run are each ONE contiguous segment —
  operands are packed tile-major (every tile's bytes aligned up to the
  32 B DBB burst), which is what keeps whole-workload traces at
  O(tile-visits) segments instead of O(tiles).

Reuse regimes (the NVDLA ``weight_passes`` analogy, per operand):

* a weight stripe (K x n_tile bytes) that fits the weight SRAM is
  fetched once; otherwise it re-streams once per M block —
  ``weight_passes[n] = n_m`` — the temporal-reuse pattern whose LLC
  behaviour the paper measures on NVDLA;
* the input operand is fetched once if all of A fits the input SRAM,
  else once per N stripe; outputs are written exactly once.

Traffic and compute-cycle totals are **visit-order invariant** by
construction: they are sums over the tile set, and first-fetch
accounting follows the reuse regime, not the loop index — the
hypothesis suite (tests/test_npu.py) replays random visit permutations
to pin that.  Timing mirrors ``repro.core.accelerator``: per-op
``compute = sum over tiles of (m + k + n + overhead)``, memory from
burst latency / MLP with a DRAM bandwidth floor, hit rates either the
closed-form stream model or — ``mode="simulated"`` — the exact segment
engine's per-op measurements folded by stream.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.core import traces
from repro.core.accelerator import (
    MemSystemConfig,
    _fold_op_stream_rates,
    _stream_hit_rate,
)
from repro.core.traces import BURST_BYTES, Segment

# NPU DBB address map.  Weights pack from traces.WEIGHT_REGION (0x0)
# with a hard heap budget; feature maps ping-pong between two regions
# placed above the heap and *below* the sweep co-runner regions at
# 0x4000_0000 (repro.core.sweep._corunner_spans) so campaign lanes
# never alias, and below int32 so the vmapped lane engine's 32-bit
# metadata holds every address.  Bases are staggered by distinct 2 KiB
# DRAM-row offsets, same rationale as traces.FMAP_REGION_A/B.
NPU_WEIGHT_BUDGET = 0x2000_0000            # 512 MiB weight heap
NPU_FMAP_REGION_A = 0x2000_0000 + 13 * 2048
NPU_FMAP_REGION_B = 0x2C00_0000 + 26 * 2048


@dataclasses.dataclass(frozen=True)
class NPUConfig:
    """One systolic-array instance: PE grid + SRAM sizing + timing."""
    rows: int = 16                 # K (reduction) dimension of the grid
    cols: int = 16                 # N (output) dimension of the grid
    ifm_buf_bytes: int = 64 * 1024
    wgt_buf_bytes: int = 64 * 1024
    acc_buf_bytes: int = 32 * 1024
    elem_bytes: int = 1            # int8 operands (the paper's int8 path)
    acc_bytes: int = 4             # int32 accumulators
    freq_hz: float = 3.2e9         # shared SoC clock (paper FireSim config)
    mlp: float = 3.1               # DBB memory-level parallelism
    tile_overhead_cycles: int = 8  # weight-load / drain bubble per tile
    op_overhead_cycles: int = 4000  # descriptor programming per GemmOp

    def __post_init__(self):
        for f in ("rows", "cols", "ifm_buf_bytes", "wgt_buf_bytes",
                  "acc_buf_bytes", "elem_bytes", "acc_bytes"):
            if getattr(self, f) <= 0:
                raise ValueError(f"NPUConfig.{f} must be positive, got "
                                 f"{getattr(self, f)}")

    @property
    def m_tile(self) -> int:
        """Input rows streamed per accumulation block: bounded by the
        input SRAM (one k-tile column of the streamed operand) and the
        accumulator SRAM (one n-tile row of partials)."""
        by_ifm = self.ifm_buf_bytes // (self.rows * self.elem_bytes)
        by_acc = self.acc_buf_bytes // (self.cols * self.acc_bytes)
        return max(1, min(by_ifm, by_acc))

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.rows * self.cols


@dataclasses.dataclass(frozen=True)
class GemmOp:
    """One tiled GEMM: ``(m x k) @ (k x n)`` — the NPU's unit of work
    (a conv layer arrives here already im2col-lowered)."""
    name: str
    m: int
    k: int
    n: int

    def __post_init__(self):
        if self.m <= 0 or self.k <= 0 or self.n <= 0:
            raise ValueError(f"GemmOp dims must be positive, got "
                             f"m={self.m} k={self.k} n={self.n}")

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


def _align(nbytes: int) -> int:
    """Tile bytes aligned up to the 32 B DBB burst — the packing rule
    that keeps every tile's byte run an exact whole number of bursts
    (so segment expansion covers operand footprints with no gaps and
    no fractional-burst overlaps)."""
    return -(-nbytes // BURST_BYTES) * BURST_BYTES


def _sizes(total: int, tile: int) -> tuple[int, ...]:
    full, rem = divmod(total, tile)
    return (tile,) * full + ((rem,) if rem else ())


class GemmSchedule:
    """Host-side schedule of one ``GemmOp`` on one ``NPUConfig``: tile
    block sizes, packed operand layouts (byte offsets), reuse regimes,
    and the closed-form traffic/cycle totals.  Pure function of
    (op, cfg); memoized via :func:`schedule`."""

    def __init__(self, op: GemmOp, cfg: NPUConfig):
        self.op, self.cfg = op, cfg
        self.m_szs = _sizes(op.m, cfg.m_tile)
        self.k_szs = _sizes(op.k, cfg.rows)
        self.n_szs = _sizes(op.n, cfg.cols)
        self.n_m, self.n_k, self.n_n = (len(self.m_szs), len(self.k_szs),
                                        len(self.n_szs))
        e = cfg.elem_bytes
        # weight layout: stripe-major (n), k-tiles contiguous in-stripe
        self.stripe_bytes = tuple(
            sum(_align(k * n * e) for k in self.k_szs) for n in self.n_szs)
        self.stripe_off = _cum(self.stripe_bytes)
        # input layout: m-block-major, k-tiles contiguous in-block
        self.mblock_bytes = tuple(
            sum(_align(m * k * e) for k in self.k_szs) for m in self.m_szs)
        self.mblock_off = _cum(self.mblock_bytes)
        # output layout: n-major, m-minor (canonical, order-independent)
        self.otile_bytes = tuple(
            tuple(_align(m * n * e) for m in self.m_szs)
            for n in self.n_szs)
        col = tuple(sum(row) for row in self.otile_bytes)
        col_off = _cum(col)
        self.otile_off = tuple(
            tuple(col_off[j] + off for off in _cum(row))
            for j, row in enumerate(self.otile_bytes))
        # reuse regimes (order-invariant by definition — see module doc)
        self.weight_passes = tuple(
            1 if sb <= cfg.wgt_buf_bytes else self.n_m
            for sb in self.stripe_bytes)
        self.weight_footprint = sum(self.stripe_bytes)
        self.ifmap_footprint = sum(self.mblock_bytes)
        self.ofmap_footprint = sum(col)
        self.ifmap_passes = (1 if self.ifmap_footprint <= cfg.ifm_buf_bytes
                             else self.n_n)

    @property
    def weight_traffic(self) -> int:
        return sum(sb * p for sb, p in zip(self.stripe_bytes,
                                           self.weight_passes))

    @property
    def ifmap_traffic(self) -> int:
        return self.ifmap_footprint * self.ifmap_passes

    @property
    def ofmap_traffic(self) -> int:
        return self.ofmap_footprint

    @property
    def total_tiles(self) -> int:
        return self.n_m * self.n_k * self.n_n

    @property
    def compute_cycles(self) -> int:
        """Sum over every (m, k, n) tile of its systolic pass —
        ``m_sz`` streaming cycles + ``k_sz + n_sz`` fill/drain + the
        fixed tile overhead.  A sum over the tile *set*, so any visit
        order totals identically (the tiling-invariance property)."""
        op, c = self.op, self.cfg.tile_overhead_cycles
        return (self.n_n * self.n_k * op.m + self.n_m * self.n_n * op.k
                + self.n_m * self.n_k * op.n + self.total_tiles * c)

    def visits(self, order="nm") -> list[tuple[int, int]]:
        """The (n, m) tile-visit sequence.  ``"nm"`` is the canonical
        weight-stationary order (n outer); ``"mn"`` streams m outer; an
        explicit sequence of (n, m) pairs must be a permutation of the
        full visit set."""
        if order == "nm":
            return [(n, m) for n in range(self.n_n)
                    for m in range(self.n_m)]
        if order == "mn":
            return [(n, m) for m in range(self.n_m)
                    for n in range(self.n_n)]
        visits = [(int(n), int(m)) for n, m in order]
        if sorted(visits) != self.visits("nm"):
            raise ValueError(
                f"explicit visit order must be a permutation of the "
                f"{self.n_n}x{self.n_m} (n, m) tile grid")
        return visits


def _cum(sizes) -> tuple[int, ...]:
    out, acc = [], 0
    for s in sizes:
        out.append(acc)
        acc += s
    return tuple(out)


@functools.lru_cache(maxsize=4096)
def schedule(op: GemmOp, cfg: NPUConfig) -> GemmSchedule:
    return GemmSchedule(op, cfg)


# --------------------------------------------------------------------------
# command stream -> compressed DBB segments
# --------------------------------------------------------------------------
def op_segments(op: GemmOp, cfg: NPUConfig, weight_base: int,
                ifmap_base: int, ofmap_base: int,
                order="nm") -> list[Segment]:
    """One GemmOp's DBB streams as compressed segments in tile-visit
    order: per (n, m) visit, the weight k-stripe (re-streamed or
    first-fetch per its reuse regime), the input k-run, and the output
    tile write — each one contiguous segment (see module doc).  The
    segment sizes are exactly the schedule's packed layouts, so the
    per-stream traffic equals ``GemmSchedule.{weight,ifmap,ofmap}_
    traffic`` bytes for *any* visit order."""
    s = schedule(op, cfg)
    segs: list[Segment] = []
    seen_n: set[int] = set()
    seen_m: set[int] = set()
    for n, m in s.visits(order):
        if s.weight_passes[n] > 1 or n not in seen_n:
            segs.append(Segment(weight_base + s.stripe_off[n], BURST_BYTES,
                                s.stripe_bytes[n] // BURST_BYTES, "weight"))
        if s.ifmap_passes > 1 or m not in seen_m:
            segs.append(Segment(ifmap_base + s.mblock_off[m], BURST_BYTES,
                                s.mblock_bytes[m] // BURST_BYTES, "ifmap"))
        segs.append(Segment(ofmap_base + s.otile_off[n][m], BURST_BYTES,
                            s.otile_bytes[n][m] // BURST_BYTES, "ofmap"))
        seen_n.add(n)
        seen_m.add(m)
    return segs


def _iter_op_segments(ops, cfg: NPUConfig, order="nm"):
    """Lazily yield each op's segment list over the NPU address map —
    the shared walk behind ``workload_op_segments`` / ``npu_chunks``
    (lazy so windowed consumers stop compiling once they have enough
    bursts)."""
    fmap_span = NPU_FMAP_REGION_B - NPU_FMAP_REGION_A
    w_cursor = traces.WEIGHT_REGION
    regions = (NPU_FMAP_REGION_A, NPU_FMAP_REGION_B)
    for i, op in enumerate(ops):
        s = schedule(op, cfg)
        if w_cursor + s.weight_footprint > \
                traces.WEIGHT_REGION + NPU_WEIGHT_BUDGET:
            raise ValueError(
                f"op {op.name!r} overruns the NPU weight heap: cursor "
                f"{w_cursor:#x} + {s.weight_footprint:#x} bytes exceeds "
                f"the {NPU_WEIGHT_BUDGET:#x}-byte budget — shrink the "
                "workload or split it into frames")
        if max(s.ifmap_footprint, s.ofmap_footprint) > fmap_span:
            raise ValueError(
                f"op {op.name!r} feature map "
                f"({max(s.ifmap_footprint, s.ofmap_footprint):#x} bytes) "
                f"overruns the {fmap_span:#x}-byte NPU fmap region")
        yield op_segments(op, cfg, w_cursor, regions[i % 2],
                          regions[(i + 1) % 2], order)
        w_cursor += s.weight_footprint


def workload_op_segments(ops, cfg: NPUConfig | None = None,
                         order="nm") -> list[list[Segment]]:
    """Per-op DBB streams over the NPU address map: weights packed from
    ``traces.WEIGHT_REGION`` in op order (heap budget enforced),
    feature maps ping-ponging between the two NPU regions so a
    chain-shaped workload reads where its producer wrote (the same
    approximation ``traces.network_op_segments`` makes).  Raises
    ``ValueError`` when an operand overruns its region — and
    ``traces.Segment`` itself rejects anything past the 40-bit DBB
    address space, so a runaway GemmOp can never emit a trace the DRAM
    model cannot address."""
    return list(_iter_op_segments(ops, cfg or NPUConfig(), order))


def workload_trace(ops, cfg: NPUConfig | None = None,
                   order="nm") -> list[Segment]:
    """The whole workload's compressed DBB stream at stream granularity
    (the flattened ``workload_op_segments``)."""
    return [seg for op_segs in workload_op_segments(ops, cfg, order)
            for seg in op_segs]


def npu_chunks(ops, cfg: NPUConfig | None = None, chunk_bursts: int = 16,
               order="nm", max_bursts: int | None = None) -> list[Segment]:
    """The NPU command stream compiled to arbiter-interleaved
    ``(base, stride, count)`` DBB segments: per op, the weight/input/
    output streams round-robin at ``chunk_bursts`` granularity
    (``traces.interleave`` — the same DBB arbiter model NVDLA windows
    use), ops back to back.  ``max_bursts`` stops compiling once that
    many bursts have been emitted (the clip still lands on an exact
    burst via ``traces.window``) — full-workload interleaved streams
    run to millions of chunks, and windowed consumers only need a
    prefix.  This is the campaign/sweep trace source for
    ``backend="npu"`` points."""
    out: list[Segment] = []
    emitted = 0
    for op_segs in _iter_op_segments(ops, cfg or NPUConfig(), order):
        chunked = traces.interleave(op_segs, chunk_bursts)
        out.extend(chunked)
        emitted += sum(s.count for s in chunked)
        if max_bursts is not None and emitted >= max_bursts:
            break
    return traces.window(out, max_bursts) if max_bursts is not None else out


def default_npu_window(name: str = "yolov3", *,
                       cfg: NPUConfig | None = None,
                       max_bursts: int = 4096,
                       chunk_bursts: int = 16) -> list[Segment]:
    """A representative NPU DBB window for sweeps: the named zoo
    workload's interleaved stream clipped to its first ``max_bursts``
    accesses (the NPU analogue of ``traces.default_dbb_window``)."""
    return npu_chunks(workload(name), cfg, chunk_bursts,
                      max_bursts=max_bursts)


# --------------------------------------------------------------------------
# model-zoo GEMM workloads
# --------------------------------------------------------------------------
def yolov3_gemms(max_layers: int | None = None) -> tuple[GemmOp, ...]:
    """YOLOv3's conv layers as im2col GEMMs: M = out_h*out_w spatial
    positions, K = cin*k*k patch elements, N = cout filters — the same
    66 GOP frame the NVDLA path runs, re-lowered for a GEMM engine."""
    from repro.core import yolov3

    ops = tuple(GemmOp(f"conv{la.index}", m=la.out_h * la.out_w,
                       k=la.cin * la.ksize * la.ksize, n=la.cout)
                for la in yolov3.LAYERS if la.kind == "conv")
    return ops[:max_layers] if max_layers else ops


def transformer_decode_gemms(arch: str = "qwen2-0.5b", *, batch: int = 8,
                             include_head: bool = True
                             ) -> tuple[GemmOp, ...]:
    """One decode step's projection GEMMs (M = decode batch): QKV,
    attention output, the (gated) MLP pair per layer, plus the LM
    head."""
    from repro.configs import get_config

    c = get_config(arch)
    qkv_n = (c.num_heads + 2 * c.num_kv_heads) * c.head_dim
    up_n = (2 if c.gated_mlp else 1) * c.d_ff
    ops: list[GemmOp] = []
    for i in range(c.num_layers):
        ops += [GemmOp(f"l{i}.qkv", batch, c.d_model, qkv_n),
                GemmOp(f"l{i}.attn_out", batch,
                       c.num_heads * c.head_dim, c.d_model),
                GemmOp(f"l{i}.mlp_up", batch, c.d_model, up_n),
                GemmOp(f"l{i}.mlp_down", batch, c.d_ff, c.d_model)]
    if include_head:
        ops.append(GemmOp("lm_head", batch, c.d_model, c.vocab_size))
    return tuple(ops)


def mamba2_decode_gemms(arch: str = "mamba2-130m", *, batch: int = 8
                        ) -> tuple[GemmOp, ...]:
    """One mamba-2 decode step's projections: the fused input
    projection (x/z branches + B/C + dt heads) and the output
    projection per layer (the SSD state update itself is elementwise —
    not GEMM work)."""
    from repro.configs import get_config

    c = get_config(arch)
    in_n = (2 * c.ssm_d_inner + 2 * c.ssm_ngroups * c.ssm_state
            + c.ssm_nheads)
    ops: list[GemmOp] = []
    for i in range(c.num_layers):
        ops += [GemmOp(f"l{i}.in_proj", batch, c.d_model, in_n),
                GemmOp(f"l{i}.out_proj", batch, c.ssm_d_inner, c.d_model)]
    return tuple(ops)


def whisper_encoder_gemms(arch: str = "whisper-tiny"
                          ) -> tuple[GemmOp, ...]:
    """The whisper audio encoder's GEMMs over a 30 s window: M =
    encoder_len frames through self-attention QKV/out and the MLP pair
    per encoder layer — a large-M workload, unlike decode."""
    from repro.configs import get_config

    c = get_config(arch)
    d_attn = c.num_heads * c.head_dim
    ops: list[GemmOp] = []
    for i in range(c.num_encoder_layers):
        ops += [GemmOp(f"enc{i}.qkv", c.encoder_len, c.d_model, 3 * d_attn),
                GemmOp(f"enc{i}.attn_out", c.encoder_len, d_attn, c.d_model),
                GemmOp(f"enc{i}.mlp_up", c.encoder_len, c.d_model, c.d_ff),
                GemmOp(f"enc{i}.mlp_down", c.encoder_len, c.d_ff,
                       c.d_model)]
    return tuple(ops)


WORKLOADS = {
    "yolov3": yolov3_gemms,
    "transformer_decode": transformer_decode_gemms,
    "mamba2_decode": mamba2_decode_gemms,
    "whisper_encoder": whisper_encoder_gemms,
}


@functools.lru_cache(maxsize=8)
def workload(name: str) -> tuple[GemmOp, ...]:
    """The named zoo workload at its default scale (memoized — config
    lookups and the GEMM lists are pure)."""
    if name not in WORKLOADS:
        raise ValueError(f"unknown NPU workload {name!r}; "
                         f"known: {sorted(WORKLOADS)}")
    return WORKLOADS[name]()


# --------------------------------------------------------------------------
# timing model (mirrors repro.core.accelerator)
# --------------------------------------------------------------------------
def op_cycles(op: GemmOp, cfg: NPUConfig, mem: MemSystemConfig,
              hit_rates: tuple[float, float, float] | None = None) -> dict:
    """One GemmOp's cycle breakdown on the NPU behind the shared memory
    system — the same structure as ``accelerator.op_cycles``:
    ``max(compute, memory) + overhead`` with memory from burst latency
    over the measured-or-modeled (weight, ifmap, ofmap) LLC hit rates,
    floored by the DRAM bandwidth share."""
    s = schedule(op, cfg)
    compute = float(s.compute_cycles)

    t_dram = mem.t_dram_cycles + mem.extra_dram_latency + mem.bus_delay_cycles
    t_llc = mem.t_llc_cycles + mem.bus_delay_cycles
    if hit_rates is not None:
        scale = 1.0 - mem.llc_eviction_prob
        h_w, h_i, h_o = (h * scale for h in hit_rates)
    else:
        h_w = h_i = h_o = _stream_hit_rate(mem)

    def stream_cycles(traffic, h):
        if traffic == 0:
            return 0.0
        lat = h * t_llc + (1.0 - h) * t_dram
        return (traffic / BURST_BYTES) * lat / cfg.mlp

    latency_cycles = (stream_cycles(s.weight_traffic, h_w)
                      + stream_cycles(s.ifmap_traffic, h_i)
                      + stream_cycles(s.ofmap_traffic, h_o))
    miss_bytes = (s.weight_traffic * (1 - h_w)
                  + s.ifmap_traffic * (1 - h_i)
                  + s.ofmap_traffic * (1 - h_o))
    bw_bytes_per_cycle = (mem.dram.peak_bw / cfg.freq_hz) * mem.dram_bw_share
    memory = max(latency_cycles, miss_bytes / bw_bytes_per_cycle)
    total = max(compute, memory) + cfg.op_overhead_cycles
    return {"compute": compute, "memory": memory, "total": total,
            "hit_rates": (h_w, h_i, h_o),
            "utilization": op.macs / (cfg.peak_macs_per_cycle * compute),
            "traffic": (s.weight_traffic, s.ifmap_traffic,
                        s.ofmap_traffic)}


def op_stream_hit_rates(ops, cfg: NPUConfig, mem: MemSystemConfig,
                        max_ops: int | None = None
                        ) -> list[tuple[float, float, float]]:
    """Exact per-op (weight, ifmap, ofmap) LLC hit rates of the NPU
    workload from the segment engine — one pass over the whole
    workload trace with LLC state carried across ops, folded by stream
    exactly like the NVDLA path (this is what ``mode="simulated"``
    feeds ``op_cycles``)."""
    from repro.core.cache import simulate_segments

    ops = tuple(ops)[:max_ops] if max_ops else tuple(ops)
    if mem.llc is None:
        return [(0.0, 0.0, 0.0)] * len(ops)
    per_op = workload_op_segments(ops, cfg)
    flat = [s for segs in per_op for s in segs]
    res = simulate_segments(flat, mem.llc, per_segment=True)
    return _fold_op_stream_rates(per_op, res.per_segment_hits)


def npu_time_s(ops, *, npu: NPUConfig | None = None,
               mem: MemSystemConfig | None = None, mode: str = "model",
               hit_rates: list | None = None) -> dict:
    """NPU-side workload time — the ``accel_time_s`` twin.
    ``mode="model"`` uses the closed-form sequential-stream hit rates;
    ``mode="simulated"`` measures every op's rates with the exact
    segment simulator on the op's real DBB trace (``hit_rates``
    short-circuits the simulation when the caller already has them)."""
    npu = npu or NPUConfig()
    mem = mem or MemSystemConfig()
    ops = tuple(ops)
    if mode not in ("model", "simulated"):
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "simulated" and hit_rates is None:
        hit_rates = op_stream_hit_rates(ops, npu, mem)
    if hit_rates is not None and len(hit_rates) != len(ops):
        raise ValueError(
            f"{len(hit_rates)} hit-rate tuples for {len(ops)} GEMM ops — "
            "hit_rates must cover every op of this workload")
    if hit_rates is None:
        per_layer = [op_cycles(op, npu, mem) for op in ops]
    else:
        per_layer = [op_cycles(op, npu, mem, hit_rates=hr)
                     for op, hr in zip(ops, hit_rates)]
    cycles = sum(p["total"] for p in per_layer)
    return {
        "cycles": cycles,
        "seconds": cycles / npu.freq_hz,
        "per_layer": per_layer,
        "mode": mode,
        "compute_bound_layers": sum(
            1 for p in per_layer if p["compute"] >= p["memory"]),
    }


def decode_weight_segments(weight_bytes: int, cfg: NPUConfig | None = None,
                           *, m: int = 1, k: int = 4096,
                           base: int = traces.WEIGHT_REGION
                           ) -> list[Segment]:
    """One decode step's parameter read as the NPU would fetch it: the
    active weights modeled as a (m x k x n) GEMM's weight stream under
    the weight-stationary schedule — per-stripe segments, with
    re-stream passes appearing exactly when a stripe outgrows the
    weight SRAM while the batch spans multiple m tiles.  This is the
    serving oracle's ``backend="npu"`` weight stream
    (``repro.serve.oracle``)."""
    cfg = cfg or NPUConfig()
    k = max(1, min(k, weight_bytes))
    n = max(1, -(-weight_bytes // (k * cfg.elem_bytes)))
    op = GemmOp("decode_weights", m=max(1, m), k=k, n=n)
    return [s for s in op_segments(op, cfg, base, NPU_FMAP_REGION_A,
                                   NPU_FMAP_REGION_B)
            if s.stream == "weight"]
