"""NVDLA (nv_large) performance model behind a shared LLC + DRAM.

Timing per AccelOp (all in accelerator cycles; NVDLA and the cores share
one 3.2 GHz clock in the paper's FireSim config):

    compute = MACs / (2048 * util)          util = min(1, cin*k*k / 64)
    memory  = bursts * avg_latency / MLP    (latency-bound DBB reads)
              floored by traffic / DRAM-bytes-per-cycle (bandwidth bound)
    layer   = max(compute, memory) + fixed descriptor overhead

The LLC model is the *stream-locality* closed form validated against the
exact simulator in ``repro.core.cache`` (tests/test_paper_core.py):
NVDLA's DBB bursts are 32 B and its streams are sequential, so for block
size B the steady-state hit rate is 1 - 32/B — spatial locality only.
Temporal reuse lives in the 512 KiB conv buffer, NOT the LLC (the paper's
central observation: capacity barely matters, block size does).  A small
capacity term survives: an ifmap re-read hits if its producer's ofmap is
still resident (possible only when ofmap + stream footprint fit).

Calibration: {t_llc, t_dram, MLP, overhead} are fit once to the paper's
baseline (67 ms/frame on NVDLA, Table 1 config) and then *held fixed*
across every LLC-sweep and interference experiment — the sweeps are
predictions of the model, compared against Fig. 5/6 in tests.
"""
from __future__ import annotations

import dataclasses

from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig
from repro.core.runtime import AccelOp, CommandStream

BURST_BYTES = 32   # NVDLA DBB minimum burst (the paper, sec. 4.1)


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    macs: int = 2048
    conv_buf_bytes: int = 512 * 1024
    freq_hz: float = 3.2e9
    atomic_c: int = 64            # nv_large atomic input-channel depth
    mlp: float = 3.1              # effective DBB memory-level parallelism
    layer_overhead_cycles: int = 12_000   # CSB programming + drain per op


@dataclasses.dataclass(frozen=True)
class MemSystemConfig:
    llc: LLCConfig | None = LLCConfig()
    dram: DRAMConfig = DRAMConfig()
    t_llc_cycles: float = 82.0    # LLC hit latency seen by the DBB
    t_dram_cycles: float = 150.0  # row-hit DRAM latency incl. bus/NoC
    # interference state (set by repro.core.interference)
    extra_dram_latency: float = 0.0
    dram_bw_share: float = 1.0
    llc_eviction_prob: float = 0.0
    bus_delay_cycles: float = 0.0


STREAM_CONFLICT_BLOCKS = 3.0   # competing streams + writebacks per set


def _stream_hit_rate(mem: MemSystemConfig, *, resident_bonus: bool = False,
                     resident_frac: float = 0.0) -> float:
    """LLC hit rate of a sequential 32 B-burst stream.

    spatial term: 1 - 32/B.  In a *tiny* cache the freshly-filled block can
    be conflict-evicted by the other interleaved streams (weights/ifmap/
    ofmap + writebacks) before its remaining bursts return — the survival
    factor n/(n + c) reproduces the paper's mild capacity slope
    (1.17x @ 0.5 KiB -> 1.28x @ 64 KiB, 64 B blocks)."""
    if mem.llc is None:
        return 0.0
    spatial = max(0.0, 1.0 - BURST_BYTES / mem.llc.block_bytes)
    n_blocks = mem.llc.sets * mem.llc.ways
    survive = n_blocks / (n_blocks + STREAM_CONFLICT_BLOCKS)
    h = spatial * survive
    h = h + (1.0 - h) * (resident_frac if resident_bonus else 0.0)
    return h * (1.0 - mem.llc_eviction_prob)


def _residency_fraction(op: AccelOp, mem: MemSystemConfig) -> float:
    """Fraction of ifmap reads that hit because the producer's ofmap is
    still LLC-resident.  Weak by construction — between the producer's
    write and this op's ifmap read, this op's own *weight stream* has
    already swept the cache, so residency needs llc_size > weight_traffic
    with only the remainder holding ofmap blocks.  This is why the paper
    sees only a mild capacity slope even at 4 MiB."""
    if mem.llc is None or op.prev_ofmap_bytes == 0:
        return 0.0
    leftover = mem.llc.size_bytes - op.weight_traffic
    if leftover <= 0:
        return 0.0
    return 0.5 * min(1.0, leftover / op.prev_ofmap_bytes)


def op_cycles(op: AccelOp, acc: AccelConfig, mem: MemSystemConfig) -> dict:
    l = op.layer
    if op.macs:
        util = min(1.0, (l.cin * l.ksize * l.ksize) / acc.atomic_c)
        compute = op.macs / (acc.macs * util)
    else:
        compute = op.ifmap_traffic / 32.0   # SDP elementwise throughput

    t_dram = (mem.t_dram_cycles + mem.extra_dram_latency)
    t_llc = mem.t_llc_cycles + mem.bus_delay_cycles
    t_dram = t_dram + mem.bus_delay_cycles

    h_w = _stream_hit_rate(mem)
    h_i = _stream_hit_rate(mem, resident_bonus=True,
                           resident_frac=_residency_fraction(op, mem))
    h_o = _stream_hit_rate(mem)

    def stream_cycles(traffic, h):
        if traffic == 0:
            return 0.0
        bursts = traffic / BURST_BYTES
        lat = h * t_llc + (1.0 - h) * t_dram
        return bursts * lat / acc.mlp

    latency_cycles = (stream_cycles(op.weight_traffic, h_w)
                      + stream_cycles(op.ifmap_traffic, h_i)
                      + stream_cycles(op.ofmap_traffic, h_o))
    # DRAM bandwidth floor: only misses reach DRAM
    miss_bytes = (op.weight_traffic * (1 - h_w)
                  + op.ifmap_traffic * (1 - h_i)
                  + op.ofmap_traffic * (1 - h_o))
    bw_bytes_per_cycle = (mem.dram.peak_bw / acc.freq_hz) * mem.dram_bw_share
    bw_cycles = miss_bytes / bw_bytes_per_cycle
    memory = max(latency_cycles, bw_cycles)
    total = max(compute, memory) + acc.layer_overhead_cycles
    return {"compute": compute, "memory": memory, "total": total,
            "hit_rates": (h_w, h_i, h_o)}


def accel_time_s(stream: CommandStream, acc: AccelConfig,
                 mem: MemSystemConfig) -> dict:
    per_layer = [op_cycles(op, acc, mem) for op in stream.accel_ops]
    cycles = sum(p["total"] for p in per_layer)
    return {
        "cycles": cycles,
        "seconds": cycles / acc.freq_hz,
        "per_layer": per_layer,
        "compute_bound_layers": sum(
            1 for p in per_layer if p["compute"] >= p["memory"]),
    }
