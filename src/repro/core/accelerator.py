"""NVDLA (nv_large) performance model behind a shared LLC + DRAM.

Timing per AccelOp (all in accelerator cycles; NVDLA and the cores share
one 3.2 GHz clock in the paper's FireSim config):

    compute = MACs / (2048 * util)          util = min(1, cin*k*k / 64)
    memory  = bursts * avg_latency / MLP    (latency-bound DBB reads)
              floored by traffic / DRAM-bytes-per-cycle (bandwidth bound)
    layer   = max(compute, memory) + fixed descriptor overhead

The LLC model is the *stream-locality* closed form validated against the
exact simulator in ``repro.core.cache`` (tests/test_paper_core.py):
NVDLA's DBB bursts are 32 B and its streams are sequential, so for block
size B the steady-state hit rate is 1 - 32/B — spatial locality only.
Temporal reuse lives in the 512 KiB conv buffer, NOT the LLC (the paper's
central observation: capacity barely matters, block size does).  A small
capacity term survives: an ifmap re-read hits if its producer's ofmap is
still resident (possible only when ofmap + stream footprint fit).

Calibration: {t_llc, t_dram, MLP, overhead} are fit once to the paper's
baseline (67 ms/frame on NVDLA, Table 1 config) and then *held fixed*
across every LLC-sweep and interference experiment — the sweeps are
predictions of the model, compared against Fig. 5/6 in tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig
from repro.core.runtime import AccelOp, CommandStream

BURST_BYTES = 32   # NVDLA DBB minimum burst (the paper, sec. 4.1)


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    macs: int = 2048
    conv_buf_bytes: int = 512 * 1024
    freq_hz: float = 3.2e9
    atomic_c: int = 64            # nv_large atomic input-channel depth
    mlp: float = 3.1              # effective DBB memory-level parallelism
    layer_overhead_cycles: int = 12_000   # CSB programming + drain per op


@dataclasses.dataclass(frozen=True)
class MemSystemConfig:
    llc: LLCConfig | None = LLCConfig()
    dram: DRAMConfig = DRAMConfig()
    t_llc_cycles: float = 82.0    # LLC hit latency seen by the DBB
    t_dram_cycles: float = 150.0  # row-hit DRAM latency incl. bus/NoC
    # interference state (set by repro.core.interference)
    extra_dram_latency: float = 0.0
    dram_bw_share: float = 1.0
    llc_eviction_prob: float = 0.0
    bus_delay_cycles: float = 0.0


STREAM_CONFLICT_BLOCKS = 3.0   # competing streams + writebacks per set


def _stream_hit_rate(mem: MemSystemConfig, *, resident_bonus: bool = False,
                     resident_frac: float = 0.0) -> float:
    """LLC hit rate of a sequential 32 B-burst stream.

    spatial term: 1 - 32/B.  In a *tiny* cache the freshly-filled block can
    be conflict-evicted by the other interleaved streams (weights/ifmap/
    ofmap + writebacks) before its remaining bursts return — the survival
    factor n/(n + c) reproduces the paper's mild capacity slope
    (1.17x @ 0.5 KiB -> 1.28x @ 64 KiB, 64 B blocks)."""
    if mem.llc is None:
        return 0.0
    spatial = max(0.0, 1.0 - BURST_BYTES / mem.llc.block_bytes)
    n_blocks = mem.llc.sets * mem.llc.ways
    survive = n_blocks / (n_blocks + STREAM_CONFLICT_BLOCKS)
    h = spatial * survive
    h = h + (1.0 - h) * (resident_frac if resident_bonus else 0.0)
    return h * (1.0 - mem.llc_eviction_prob)


def _residency_fraction(op: AccelOp, mem: MemSystemConfig) -> float:
    """Fraction of ifmap reads that hit because the producer's ofmap is
    still LLC-resident.  Weak by construction — between the producer's
    write and this op's ifmap read, this op's own *weight stream* has
    already swept the cache, so residency needs llc_size > weight_traffic
    with only the remainder holding ofmap blocks.  This is why the paper
    sees only a mild capacity slope even at 4 MiB."""
    if mem.llc is None or op.prev_ofmap_bytes == 0:
        return 0.0
    leftover = mem.llc.size_bytes - op.weight_traffic
    if leftover <= 0:
        return 0.0
    return 0.5 * min(1.0, leftover / op.prev_ofmap_bytes)


def op_cycles(op: AccelOp, acc: AccelConfig, mem: MemSystemConfig,
              hit_rates: tuple[float, float, float] | None = None) -> dict:
    """One AccelOp's cycle breakdown.  ``hit_rates`` overrides the
    closed-form stream-locality model with measured (weight, ifmap,
    ofmap) LLC hit rates — the sim-driven mode feeds the exact segment
    simulator's per-layer rates here (``op_stream_hit_rates``).  The
    interference eviction term still applies on top, so co-runner
    modeling composes with either source."""
    l = op.layer
    if op.macs:
        util = min(1.0, (l.cin * l.ksize * l.ksize) / acc.atomic_c)
        compute = op.macs / (acc.macs * util)
    else:
        compute = op.ifmap_traffic / 32.0   # SDP elementwise throughput

    t_dram = (mem.t_dram_cycles + mem.extra_dram_latency)
    t_llc = mem.t_llc_cycles + mem.bus_delay_cycles
    t_dram = t_dram + mem.bus_delay_cycles

    if hit_rates is not None:
        scale = 1.0 - mem.llc_eviction_prob
        h_w, h_i, h_o = (h * scale for h in hit_rates)
    else:
        h_w = _stream_hit_rate(mem)
        h_i = _stream_hit_rate(mem, resident_bonus=True,
                               resident_frac=_residency_fraction(op, mem))
        h_o = _stream_hit_rate(mem)

    def stream_cycles(traffic, h):
        if traffic == 0:
            return 0.0
        bursts = traffic / BURST_BYTES
        lat = h * t_llc + (1.0 - h) * t_dram
        return bursts * lat / acc.mlp

    latency_cycles = (stream_cycles(op.weight_traffic, h_w)
                      + stream_cycles(op.ifmap_traffic, h_i)
                      + stream_cycles(op.ofmap_traffic, h_o))
    # DRAM bandwidth floor: only misses reach DRAM
    miss_bytes = (op.weight_traffic * (1 - h_w)
                  + op.ifmap_traffic * (1 - h_i)
                  + op.ofmap_traffic * (1 - h_o))
    bw_bytes_per_cycle = (mem.dram.peak_bw / acc.freq_hz) * mem.dram_bw_share
    bw_cycles = miss_bytes / bw_bytes_per_cycle
    memory = max(latency_cycles, bw_cycles)
    total = max(compute, memory) + acc.layer_overhead_cycles
    return {"compute": compute, "memory": memory, "total": total,
            "hit_rates": (h_w, h_i, h_o)}


def op_stream_hit_rates(stream: CommandStream, mem: MemSystemConfig,
                        max_ops: int | None = None
                        ) -> list[tuple[float, float, float]]:
    """Exact per-op (weight, ifmap, ofmap) LLC hit rates from the
    compressed segment engine, one pass over the whole network's DBB
    trace with LLC state carried across ops — so an op's ifmap reads
    really do hit on its producer's still-resident ofmap blocks, and a
    restreamed weight region really is warm.  This is what
    ``mode="simulated"`` feeds into ``op_cycles`` in place of the
    closed-form stream model (the ROADMAP item: the sim no longer just
    validates the closed form, it can drive it)."""
    from repro.core import traces
    from repro.core.cache import simulate_segments

    ops = stream.accel_ops[:max_ops] if max_ops else stream.accel_ops
    if mem.llc is None:
        return [(0.0, 0.0, 0.0)] * len(ops)
    per_op = traces.network_op_segments(stream, max_ops)
    flat = [s for segs in per_op for s in segs]
    res = simulate_segments(flat, mem.llc, per_segment=True)
    return _fold_op_stream_rates(per_op, res.per_segment_hits)


def _fold_op_stream_rates(per_op, per_segment_hits
                          ) -> list[tuple[float, float, float]]:
    """Fold flat per-segment hit counts back into per-op (weight, ifmap,
    ofmap) rates, following the op/stream structure of ``per_op``."""
    rates: list[tuple[float, float, float]] = []
    k = 0
    for segs in per_op:
        tot = {"weight": [0, 0], "ifmap": [0, 0], "ofmap": [0, 0]}
        for s in segs:
            tot[s.stream][0] += int(per_segment_hits[k])
            tot[s.stream][1] += s.count
            k += 1
        rates.append(tuple(t[0] / t[1] if t[1] else 0.0
                           for t in (tot["weight"], tot["ifmap"],
                                     tot["ofmap"])))
    return rates


def op_stream_hit_rates_grid(stream: CommandStream,
                             llc_configs: list[LLCConfig],
                             max_ops: int | None = None
                             ) -> list[list[tuple[float, float, float]]]:
    """``op_stream_hit_rates`` for a whole geometry grid at once: the
    full-network trace replays through the bucketed vmapped segment-lane
    engine (``repro.core.sweep.segment_lane_hit_counts``), so an N-point
    simulated Fig. 5 sweep costs a handful of compiled lane programs
    instead of N serial whole-frame passes.  ``max_ops`` truncates the
    stream like the pointwise function's parameter (prefix replay —
    smoke-scale grids).  Returns one per-op rate list per config,
    exactly what each ``accel_time_s(hit_rates=...)`` call needs."""
    from repro.core import traces
    from repro.core.sweep import segment_lane_hit_counts

    per_op = traces.network_op_segments(stream, max_ops)
    flat = [s for segs in per_op for s in segs]
    counts = segment_lane_hit_counts(flat, llc_configs)   # (n_cfg, S)
    return [_fold_op_stream_rates(per_op, counts[g])
            for g in range(len(llc_configs))]


def accel_time_s(stream: CommandStream, *legacy,
                 acc: AccelConfig | None = None,
                 mem: MemSystemConfig | None = None, mode: str = "model",
                 hit_rates: list | None = None) -> dict:
    """NVDLA-side frame time.  ``mode="model"`` uses the closed-form
    stream-locality hit rates (the calibrated paper model);
    ``mode="simulated"`` drives every layer's hit rates from the exact
    segment simulator on that layer's real DBB trace (``hit_rates``
    short-circuits the simulation when the caller already has them —
    e.g. a sweep reusing one simulation across co-runner counts).

    Configs are keyword-only (``acc=``, ``mem=``), matching the
    ``llc=``/``dram=``/``mix=`` convention of the sweep APIs;
    positional configs still work for one release with a
    ``DeprecationWarning``."""
    if legacy:
        if len(legacy) > 2:
            raise TypeError("accel_time_s() takes at most 2 positional "
                            f"configs, got {len(legacy)}")
        import warnings

        warnings.warn(
            "positional configs to accel_time_s() are deprecated; pass "
            "acc=/mem= keyword-only (the shared convention across the "
            "sweep/pipeline APIs)", DeprecationWarning, stacklevel=2)
        if acc is not None or (mem is not None and len(legacy) > 1):
            raise TypeError("accel_time_s() got a config both positionally "
                            "and by keyword")
        acc = legacy[0]
        if len(legacy) > 1:
            mem = legacy[1]
    if acc is None or mem is None:
        raise TypeError("accel_time_s() missing required keyword "
                        "argument(s): acc=/mem=")
    if mode not in ("model", "simulated"):
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "simulated" and hit_rates is None:
        hit_rates = op_stream_hit_rates(stream, mem)
    if hit_rates is not None and len(hit_rates) != len(stream.accel_ops):
        raise ValueError(
            f"{len(hit_rates)} hit-rate tuples for "
            f"{len(stream.accel_ops)} accel ops — hit_rates must cover "
            "every op of this stream")
    if hit_rates is None:
        per_layer = [op_cycles(op, acc, mem) for op in stream.accel_ops]
    else:
        per_layer = [op_cycles(op, acc, mem, hit_rates=hr)
                     for op, hr in zip(stream.accel_ops, hit_rates)]
    cycles = sum(p["total"] for p in per_layer)
    return {
        "cycles": cycles,
        "seconds": cycles / acc.freq_hz,
        "per_layer": per_layer,
        "mode": mode,
        "compute_bound_layers": sum(
            1 for p in per_layer if p["compute"] >= p["memory"]),
    }


def recalibrate_stream_conflict(sim_hit_rates: dict) -> dict:
    """Re-fit ``STREAM_CONFLICT_BLOCKS`` against a *simulated* Fig. 5
    grid (``repro.core.sweep.sweep_llc().sim_hit_rates``: {(size_kib,
    block): exact hit rate}).

    The closed form says h = (1 - 32/B) * n/(n + c) with n the cache's
    block count; each grid point solves for c, the fit is their median
    (robust to the few points where capacity effects the closed form
    deliberately ignores dominate), and both the shipped and fitted
    constants get an RMS report — benchmarks assert the shipped value
    stays inside the simulated fit's neighbourhood instead of drifting
    from the paper anchors."""
    from repro.core.soc import llc_config_for

    pts, fits = [], []
    for (size, block), h in sim_hit_rates.items():
        cfg = llc_config_for(size, block)
        spatial = max(0.0, 1.0 - BURST_BYTES / block)
        n = cfg.sets * cfg.ways
        pts.append((spatial, n, h))
        if 0.0 < h < spatial:
            fits.append(n * (spatial / h - 1.0))
    c_fit = float(np.median(fits)) if fits else STREAM_CONFLICT_BLOCKS

    def rms(c: float) -> float:
        err = [s * n / (n + c) - h for s, n, h in pts]
        return float(np.sqrt(np.mean(np.square(err)))) if err else 0.0

    return {"stream_conflict_blocks": c_fit,
            "shipped": STREAM_CONFLICT_BLOCKS,
            "rms_shipped": rms(STREAM_CONFLICT_BLOCKS),
            "rms_fit": rms(c_fit),
            "points": len(pts)}
