"""YOLOv3-416 network descriptor (Redmon & Farhadi 2018; darknet cfg).

The paper's benchmark: 106 layers, 66 GOP per 416x416 frame.  This module
encodes the exact darknet layer table — Darknet-53 backbone (23 residual
blocks) + the 3-scale detection head with routes and upsamples — and the
per-layer compute/traffic accounting that feeds the accelerator model.

Layer kinds and their execution target (exactly the paper's Darknet/NVDLA
split):
* ``conv``      -> NVDLA conv core (int8)            [accelerator]
* ``shortcut``  -> NVDLA SDP elementwise add          [accelerator]
* ``upsample``  -> CPU (not supported by NVDLA)       [cpu]
* ``route``     -> CPU (concat / tensor copy)         [cpu]
* ``yolo``      -> CPU (custom detection layer)       [cpu]
plus the fp32<->int8 boundary conversions the paper calls out, attached to
the cpu ops that need them.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Layer:
    index: int
    kind: str                  # conv | shortcut | route | upsample | yolo
    h: int                     # input spatial (square)
    w: int
    cin: int
    cout: int
    ksize: int = 0             # conv only
    stride: int = 1
    out_h: int = 0
    out_w: int = 0
    frm: tuple = ()            # route/shortcut source layer indices

    @property
    def macs(self) -> int:
        if self.kind != "conv":
            return 0
        return self.out_h * self.out_w * self.ksize * self.ksize \
            * self.cin * self.cout

    @property
    def weight_bytes(self) -> int:  # int8 weights
        if self.kind != "conv":
            return 0
        return self.ksize * self.ksize * self.cin * self.cout

    @property
    def ifmap_bytes(self) -> int:   # int8 activations
        return self.h * self.w * self.cin

    @property
    def ofmap_bytes(self) -> int:
        return self.out_h * self.out_w * self.cout


def _build() -> list[Layer]:
    layers: list[Layer] = []
    h = w = 416
    c = 3
    outs: list[tuple[int, int, int]] = []   # (h, w, c) per layer

    def add_conv(cout, k, stride):
        nonlocal h, w, c
        i = len(layers)
        oh, ow = h // stride, w // stride
        layers.append(Layer(i, "conv", h, w, c, cout, k, stride, oh, ow))
        h, w, c = oh, ow, cout
        outs.append((h, w, c))

    def add_shortcut(frm):
        nonlocal h, w, c
        i = len(layers)
        layers.append(Layer(i, "shortcut", h, w, c, c, 0, 1, h, w,
                            (i + frm,)))
        outs.append((h, w, c))

    def add_route(srcs):
        nonlocal h, w, c
        i = len(layers)
        abs_srcs = tuple(s if s >= 0 else i + s for s in srcs)
        hh, ww, _ = outs[abs_srcs[0]]
        cc = sum(outs[s][2] for s in abs_srcs)
        layers.append(Layer(i, "route", hh, ww, cc, cc, 0, 1, hh, ww,
                            abs_srcs))
        h, w, c = hh, ww, cc
        outs.append((h, w, c))

    def add_upsample():
        nonlocal h, w, c
        i = len(layers)
        layers.append(Layer(i, "upsample", h, w, c, c, 0, 1, h * 2, w * 2))
        h, w = h * 2, w * 2
        outs.append((h, w, c))

    def add_yolo():
        i = len(layers)
        layers.append(Layer(i, "yolo", h, w, c, c, 0, 1, h, w))
        outs.append((h, w, c))

    def res_block(c_half):
        add_conv(c_half, 1, 1)
        add_conv(c_half * 2, 3, 1)
        add_shortcut(-3)

    # ---- Darknet-53 backbone ------------------------------------------
    add_conv(32, 3, 1)            # 0
    add_conv(64, 3, 2)            # 1 downsample
    res_block(32)                 # 2-4
    add_conv(128, 3, 2)           # 5
    for _ in range(2):
        res_block(64)             # 6-11
    add_conv(256, 3, 2)           # 12
    for _ in range(8):
        res_block(128)            # 13-36 (layer 36 out: 52x52x256)
    add_conv(512, 3, 2)           # 37
    for _ in range(8):
        res_block(256)            # 38-61 (layer 61 out: 26x26x512)
    add_conv(1024, 3, 2)          # 62
    for _ in range(4):
        res_block(512)            # 63-74

    # ---- head, scale 1 (13x13) ----------------------------------------
    for _ in range(2):
        add_conv(512, 1, 1)
        add_conv(1024, 3, 1)
    add_conv(512, 1, 1)           # 79
    add_conv(1024, 3, 1)          # 80
    add_conv(255, 1, 1)           # 81
    add_yolo()                    # 82
    # ---- scale 2 (26x26) ----------------------------------------------
    add_route((-4,))              # 83 (from 79)
    add_conv(256, 1, 1)           # 84
    add_upsample()                # 85
    add_route((-1, 61))           # 86
    for _ in range(2):
        add_conv(256, 1, 1)
        add_conv(512, 3, 1)
    add_conv(256, 1, 1)           # 91
    add_conv(512, 3, 1)           # 92
    add_conv(255, 1, 1)           # 93
    add_yolo()                    # 94
    # ---- scale 3 (52x52) ----------------------------------------------
    add_route((-4,))              # 95 (from 91)
    add_conv(128, 1, 1)           # 96
    add_upsample()                # 97
    add_route((-1, 36))           # 98
    for _ in range(3):
        add_conv(128, 1, 1)
        add_conv(256, 3, 1)
    add_conv(255, 1, 1)           # 105
    add_yolo()                    # 106

    return layers


LAYERS: list[Layer] = _build()


def total_macs() -> int:
    return sum(l.macs for l in LAYERS)


def total_gops() -> float:
    """2 ops per MAC, the convention behind the paper's '66 billion ops'."""
    return 2.0 * total_macs() / 1e9


def total_weight_bytes() -> int:
    return sum(l.weight_bytes for l in LAYERS)


def accelerated(l: Layer) -> bool:
    """NVDLA executes convs and elementwise shortcuts; the rest is CPU —
    the paper's split (upsample, routes, yolo layers + fp/int casts)."""
    return l.kind in ("conv", "shortcut")
