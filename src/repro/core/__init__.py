"""The paper's primary contribution: NVDLA integrated into an SoC with a
configurable shared memory hierarchy under FAME-1 token simulation.

Subsystems (see DESIGN.md section 2 for the TPU/JAX adaptation map):
* ``yolov3``       — the benchmark network descriptor (66 GOP / frame);
* ``runtime``      — command-stream compiler (accel/CPU split, tiling);
* ``quant``        — int8 calibration for the accelerated path;
* ``accelerator``  — NVDLA nv_large timing model behind the shared LLC;
* ``npu``          — second backend: weight-stationary systolic GEMM
                     array compiling model-zoo workloads to the same
                     DBB segments (docs/npu.md);
* ``cache``        — exact set-associative LLC simulator (runtime-config)
                     with a run-length-compressed segment engine;
* ``traces``       — compressed (base, stride, count) DBB trace
                     generation from the command stream;
* ``sweep``        — vmapped multi-geometry LLC/interference sweeps
                     (one compiled program per grid);
* ``dram``         — bank/row DRAM timing model;
* ``fame1``        — token-based target-clock decoupling combinators
                     (chunked early-exit host scheduler);
* ``interference`` — BwWrite co-runner perturbations;
* ``soc``          — composition + the paper's three experiments.
"""
from repro.core.soc import (  # noqa: F401
    SoCConfig,
    interference_sweep,
    llc_sweep,
    platform_table,
    run_yolov3,
)
