"""SoC composition + the paper's three experiments as callable drivers.

Mirrors Figure 2: quad-core CPU + NVDLA behind a front-bus arbiter into a
shared LLC + DRAM.  The CPU-side cost model covers exactly the layers the
paper runs on the cores (upsample, routes, YOLO heads, fp<->int casts,
OpenMP across 4 in-order cores).

Drivers:
* ``run_yolov3``        — one frame; per-layer cycles, accel/cpu split, fps
                          (paper baseline: 67 ms accel + 66 ms CPU = 7.5 fps);
* ``llc_sweep``         — Fig. 5: speedup vs no-LLC over size x block;
* ``interference_sweep``— Fig. 6: slowdown vs #BwWrite co-runners x WSS;
* ``platform_table``    — Fig. 4: fps on NVDLA / 4xRocket / Xeon / TitanXp.
"""
from __future__ import annotations

import dataclasses

from repro.core import yolov3
from repro.core.accelerator import (
    AccelConfig,
    MemSystemConfig,
    accel_time_s,
)
from repro.core.cache import LLCConfig
from repro.core.interference import with_corunners
from repro.core.runtime import CommandStream, compile_network


@dataclasses.dataclass(frozen=True)
class CpuConfig:
    cores: int = 4
    freq_hz: float = 3.2e9
    # calibrated to the paper's measured 66 ms CPU share per frame
    # (darknet's scalar fp conversions / upsample / yolo loops on
    # in-order single-issue Rocket cores)
    elements_per_cycle_per_core: float = 0.0072


@dataclasses.dataclass(frozen=True)
class SoCConfig:
    accel: AccelConfig = AccelConfig()
    mem: MemSystemConfig = MemSystemConfig()
    cpu: CpuConfig = CpuConfig()


@dataclasses.dataclass(frozen=True)
class FrameReport:
    accel_s: float
    cpu_s: float
    detail: dict

    @property
    def frame_s(self) -> float:
        return self.accel_s + self.cpu_s

    @property
    def fps(self) -> float:
        return 1.0 / self.frame_s


def cpu_time_s(stream: CommandStream, cpu: CpuConfig) -> float:
    elems = sum(op.elements for op in stream.cpu_ops)
    rate = cpu.cores * cpu.freq_hz * cpu.elements_per_cycle_per_core
    return elems / rate


def run_yolov3(soc: SoCConfig = SoCConfig(), *, co_runners: int = 0,
               wss: str = "l1", mode: str = "model") -> FrameReport:
    """One frame.  ``mode="simulated"`` drives every layer's LLC hit
    rates from the exact segment simulator instead of the closed-form
    stream model (see ``repro.core.accelerator.accel_time_s``)."""
    stream = compile_network(conv_buf_bytes=soc.accel.conv_buf_bytes)
    mem = with_corunners(soc.mem, co_runners, wss)
    accel = accel_time_s(stream, acc=soc.accel, mem=mem, mode=mode)
    cpu_s = cpu_time_s(stream, soc.cpu)
    return FrameReport(accel_s=accel["seconds"], cpu_s=cpu_s,
                       detail={"accel": accel, "stream": stream})


# --------------------------------------------------------------------------
# Fig. 5 — LLC sweep
# --------------------------------------------------------------------------
def llc_config_for(size_kib: float, block: int) -> LLCConfig:
    """The Fig. 5 grid's geometry rule — shared by the closed-form sweep
    here and the simulated sweeps in ``repro.core.sweep`` so both always
    describe the same cache."""
    ways = min(8, max(1, int(size_kib * 1024 // block)))
    return LLCConfig(size_bytes=int(size_kib * 1024), ways=ways,
                     block_bytes=block)


def llc_sweep(sizes_kib=(0.5, 2, 8, 64, 512, 1024, 4096),
              blocks=(32, 64, 128), soc: SoCConfig = SoCConfig(),
              mode: str = "model") -> dict:
    """Speedup of the NVDLA-side time vs a no-LLC design.

    ``mode="simulated"`` replays the whole network's compressed DBB
    trace through the exact segment engine at every grid geometry —
    one bucketed vmapped lane program for the entire grid
    (``op_stream_hit_rates_grid``) — and feeds the measured per-layer
    hit rates into the timing model: the cycle-exact-over-analytical
    path.  The no-LLC baseline has nothing to simulate and is shared."""
    stream = compile_network(conv_buf_bytes=soc.accel.conv_buf_bytes)
    base = accel_time_s(
        stream, acc=soc.accel,
        mem=dataclasses.replace(soc.mem, llc=None))["seconds"]
    points = [(size, block) for block in blocks for size in sizes_kib]
    rates_grid = None
    if mode == "simulated":
        from repro.core.accelerator import op_stream_hit_rates_grid

        rates_grid = op_stream_hit_rates_grid(
            stream, [llc_config_for(s, b) for s, b in points])
    out = {"no_llc_s": base, "grid": {}}
    for i, (size, block) in enumerate(points):
        mem = dataclasses.replace(soc.mem, llc=llc_config_for(size, block))
        t = accel_time_s(
            stream, acc=soc.accel, mem=mem, mode=mode,
            hit_rates=rates_grid[i] if rates_grid else None)["seconds"]
        out["grid"][(size, block)] = base / t
    return out


# --------------------------------------------------------------------------
# Fig. 6 — interference sweep
# --------------------------------------------------------------------------
def interference_sweep(soc: SoCConfig = SoCConfig(),
                       corunners=(0, 1, 2, 3, 4)) -> dict:
    solo = run_yolov3(soc).accel_s
    out = {}
    for wss in ("l1", "llc", "dram"):
        out[wss] = {n: run_yolov3(soc, co_runners=n, wss=wss).accel_s / solo
                    for n in corunners}
    return out


# --------------------------------------------------------------------------
# Fig. 4 — platform comparison
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    peak_flops: float
    efficiency: float          # sustained fraction on darknet fp32
    source: str

    def fps(self, gops: float) -> float:
        return (self.peak_flops * self.efficiency) / (gops * 1e9)


def platform_table(soc: SoCConfig = SoCConfig()) -> dict:
    gops = yolov3.total_gops()
    nvdla = run_yolov3(soc)
    platforms = [
        # 4 in-order single-issue cores, scalar fp32 darknet: calibrated to
        # the paper's 407x NVDLA speedup claim
        Platform("4x rocket (fp32)", 4 * 3.2e9 * 2, 0.0468,
                 "calibrated: paper's 407x"),
        # 2-socket Xeon E5-2658v3: 24C/48T AVX2 @2.2GHz = 1.7 TFLOP fp32
        Platform("xeon e5-2658v3 x2 (fp32)", 1.69e12, 0.078,
                 "estimated from paper Fig. 4 bar (~2 fps)"),
        # Titan Xp: 12.15 TFLOP fp32; paper measured 41 fps
        Platform("titan xp (fp32)", 12.15e12, 0.222,
                 "calibrated: paper's 41 fps"),
    ]
    table = {"nvdla (int8)": nvdla.fps}
    table.update({p.name: p.fps(gops) for p in platforms})
    table["_meta"] = {
        "gops": gops,
        "nvdla_accel_ms": nvdla.accel_s * 1e3,
        "nvdla_cpu_ms": nvdla.cpu_s * 1e3,
        "speedup_vs_rocket": table["nvdla (int8)"] / table["4x rocket (fp32)"],
    }
    return table
