"""FAME-1 token-based target-clock decoupling, as a JAX combinator.

FireSim turns target RTL into a token simulator: every component consumes
one input token and produces one output token per *target* cycle, and is
stalled (clock-gated) on host cycles where a token is unavailable — the
paper's contribution is the Chisel pass that applies this to NVDLA's
Verilog via clock gating (Fig. 3b).

The JAX analogue: a target-cycle step function ``f(state, x) -> (state,
y)`` is wrapped so a *host* schedule of token-valid bits drives it.  On a
host cycle with no token the state passes through unchanged — clock
gating is ``lax.select`` (Fig. 3b's mux, literally).  The defining FAME-1
property — target-visible behaviour is bit-identical for every stall
pattern — holds by construction and is property-tested with randomized
schedules (tests/test_fame1.py).

``FAME1Pipeline`` chains components through single-entry token queues,
the shape of the paper's Figure 2 (NVDLA -> front bus -> LLC/DRAM model),
where a downstream stall (e.g. the memory model waiting on host DRAM)
back-pressures upstream components exactly as FireSim's channels do.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


def _select_tree(pred, new, old):
    return jax.tree.map(
        lambda a, b: jax.lax.select(
            jax.lax.broadcast(pred, a.shape) if a.ndim else pred, a, b),
        new, old)


def fame1_wrap(step_fn: Callable):
    """f(state, x) -> (state, y)  ==>  h((state,), (x, valid)) which holds
    state and emits an invalid token when `valid` is False."""

    def host_step(state, inp):
        x, valid = inp
        new_state, y = step_fn(state, x)
        state = _select_tree(valid, new_state, state)
        return state, (y, valid)

    return host_step


def run_hosted(step_fn, init_state, tokens, valid_mask):
    """Run `step_fn` under a host schedule.

    tokens: (H, ...) per-host-cycle input (entries where valid_mask is
    False are ignored); valid_mask: (H,) bool.  Returns (final_state,
    outputs (T, ...)) where T = number of valid host cycles — i.e. the
    *target*-cycle view, independent of the stall pattern.
    """
    hosted = fame1_wrap(step_fn)
    final, (ys, valids) = jax.lax.scan(hosted, init_state,
                                       (tokens, valid_mask))
    # compact to target cycles: stable order of the valid outputs
    order = jnp.argsort(~valids, stable=True)
    n_valid = jnp.sum(valids)
    compacted = jax.tree.map(lambda y: y[order], ys)
    return final, compacted, n_valid


@dataclasses.dataclass
class Component:
    """A FAME-1-transformed target component."""
    name: str
    step_fn: Callable                    # (state, x) -> (state, y)
    init_state: Any
    init_output: Any                     # token value emitted before any input


class FAME1Pipeline:
    """Chain of components with single-slot token channels between them.

    Each host cycle: component i fires iff its input channel holds a token
    and its output channel is empty (downstream consumed).  An external
    stall pattern may additionally gate any component — simulating host
    non-determinism (DRAM delays, FPGA stalls).  Target behaviour is
    invariant to that pattern (the FAME-1 guarantee).
    """

    def __init__(self, components: list[Component]):
        self.components = components

    def run(self, inputs, host_stalls=None, max_host_cycles: int | None = None):
        """inputs: (T, ...) source tokens.  host_stalls: (H, n_components)
        bool — True = stall that component that cycle."""
        n = len(self.components)
        t_total = jax.tree.leaves(inputs)[0].shape[0]
        h_total = max_host_cycles or (4 * t_total * (n + 1))
        if host_stalls is None:
            host_stalls = jnp.zeros((h_total, n), bool)
        h_total = host_stalls.shape[0]

        comp_states = tuple(c.init_state for c in self.components)
        # channel i feeds component i; channel n collects the sink.
        # channel 0 carries SOURCE tokens: initialise from the input type.
        chan_vals = (jax.tree.map(lambda a: jnp.zeros_like(a[0]), inputs),
                     ) + tuple(c.init_output for c in self.components)
        chan_full = jnp.zeros((n + 1,), bool)
        out_buf = jax.tree.map(
            lambda y: jnp.zeros((t_total,) + jnp.shape(y),
                                jnp.result_type(y)),
            self.components[-1].init_output)

        def host_cycle(carry, stalls):
            states, chans, full, src_idx, out_idx, outs = carry
            # source: push next input token into channel 0 when empty
            can_push = (~full[0]) & (src_idx < t_total)
            tok = jax.tree.map(lambda a: a[jnp.minimum(src_idx, t_total - 1)],
                               inputs)
            chans = (_select_tree(can_push, tok, chans[0]),) + chans[1:]
            full = full.at[0].set(full[0] | can_push)
            src_idx = src_idx + can_push.astype(jnp.int32)

            new_states = []
            for i, comp in enumerate(self.components):
                fire = full[i] & (~full[i + 1]) & (~stalls[i])
                s_new, y = comp.step_fn(states[i], chans[i])
                new_states.append(_select_tree(fire, s_new, states[i]))
                chans = chans[: i + 1] + (
                    _select_tree(fire, y, chans[i + 1]),) + chans[i + 2:]
                full = full.at[i].set(full[i] & ~fire)
                full = full.at[i + 1].set(full[i + 1] | fire)
            # sink: drain channel n
            drain = full[n]
            outs = jax.tree.map(
                lambda buf, v: jax.lax.select(
                    drain,
                    jax.lax.dynamic_update_index_in_dim(
                        buf, jnp.expand_dims(v, 0).astype(buf.dtype),
                        jnp.minimum(out_idx, t_total - 1), 0),
                    buf),
                outs, chans[n])
            full = full.at[n].set(False)
            out_idx = out_idx + drain.astype(jnp.int32)
            return (tuple(new_states), chans, full, src_idx, out_idx, outs), None

        carry = (comp_states, chan_vals, chan_full,
                 jnp.int32(0), jnp.int32(0), out_buf)
        (states, _, _, _, out_idx, outs), _ = jax.lax.scan(
            host_cycle, carry, host_stalls)
        return states, outs, out_idx
