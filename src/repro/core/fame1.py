"""FAME-1 token-based target-clock decoupling, as a JAX combinator.

FireSim turns target RTL into a token simulator: every component consumes
one input token and produces one output token per *target* cycle, and is
stalled (clock-gated) on host cycles where a token is unavailable — the
paper's contribution is the Chisel pass that applies this to NVDLA's
Verilog via clock gating (Fig. 3b).

The JAX analogue: a target-cycle step function ``f(state, x) -> (state,
y)`` is wrapped so a *host* schedule of token-valid bits drives it.  On a
host cycle with no token the state passes through unchanged — clock
gating is ``lax.select`` (Fig. 3b's mux, literally).  The defining FAME-1
property — target-visible behaviour is bit-identical for every stall
pattern — holds by construction and is property-tested with randomized
schedules (tests/test_fame1.py).

``FAME1Pipeline`` chains components through single-entry token queues,
the shape of the paper's Figure 2 (NVDLA -> front bus -> LLC/DRAM model),
where a downstream stall (e.g. the memory model waiting on host DRAM)
back-pressures upstream components exactly as FireSim's channels do.

Scheduler performance: the seed ran a fixed ``4*T*(n+1)`` host-cycle
scan regardless of when the sink finished.  ``FAME1Pipeline.run`` now
(a) pre-compacts the stall schedule — a host cycle on which *every*
component is stalled makes no target-visible progress that the next
cycle would not also make, so it is dropped before simulation — and
(b) replays the remaining schedule in fixed-size chunks under a
``lax.while_loop`` that exits as soon as the sink has drained all T
tokens.  Both transformations are target-invisible (the FAME-1
guarantee; equivalence is tested against the fixed-schedule path in
tests/test_sweep.py), and together they cut host cycles from
``4*T*(n+1)`` to ~``T + n`` on stall-free replay.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


def _select_tree(pred, new, old):
    return jax.tree.map(
        lambda a, b: jax.lax.select(
            jax.lax.broadcast(pred, a.shape) if a.ndim else pred, a, b),
        new, old)


def chunked_scan(step_fn, init_carry, xs, *, cont_fn,
                 chunk_len: int = 64, pow2_bucket: bool = True):
    """Token-bundle execution of a per-target-cycle ``lax.scan``: replay
    ``xs`` in ``chunk_len``-cycle bundles under a ``lax.while_loop``
    that stops as soon as ``cont_fn(carry)`` goes False — the
    ``FAME1Pipeline.run`` early-exit pattern, factored out so other
    token simulators (the NoC switch farm, ``repro.core.noc``) batch k
    target cycles per host step through one combinator.

    ``step_fn(carry, x, active) -> (carry, y)`` is one target cycle; it
    MUST be a no-op on ``active=False`` cycles (bundle padding), which
    is exactly the FAME-1 clock-gate contract — and what makes the
    result provably invariant to ``chunk_len``, including bundle sizes
    that do not divide the cycle count (tests/test_noc.py).

    ``xs`` leaves are (H, ...); the schedule is zero-padded to a whole
    number of bundles (``pow2_bucket`` rounds the bundle count to a
    power of two so similar-length schedules share a compiled program).
    Returns ``(carry, ys, bundles_run)`` where ``ys`` leaves are
    (n_bundles * chunk_len, ...) — entries past the executed bundles
    (or on inactive padding cycles) hold zeros, so per-cycle outputs
    must carry their own validity bit.  Trace under ``jit``: the bundle
    count specializes on the (static) schedule length.
    """
    if chunk_len < 1:
        raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
    xs = jax.tree.map(jnp.asarray, xs)
    h_total = jax.tree.leaves(xs)[0].shape[0]
    n_chunks = max(1, -(-h_total // chunk_len))
    if pow2_bucket:
        n_chunks = 1 << (n_chunks - 1).bit_length()
    pad = n_chunks * chunk_len - h_total
    xs_c = jax.tree.map(
        lambda a: jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]
        ).reshape((n_chunks, chunk_len) + a.shape[1:]), xs)
    act_c = (jnp.arange(n_chunks * chunk_len)
             < h_total).reshape(n_chunks, chunk_len)
    y_struct = jax.eval_shape(
        lambda c, x: step_fn(c, x, jnp.bool_(True))[1],
        init_carry, jax.tree.map(lambda a: a[0, 0], xs_c))
    ys_init = jax.tree.map(
        lambda s: jnp.zeros((n_chunks * chunk_len,) + s.shape, s.dtype),
        y_struct)

    def bundle(loop):
        ci, carry, ys_buf = loop
        carry, ys = jax.lax.scan(
            lambda c, inp: step_fn(c, inp[0], inp[1]), carry,
            (jax.tree.map(lambda a: a[ci], xs_c), act_c[ci]))
        ys_buf = jax.tree.map(
            lambda b, y: jax.lax.dynamic_update_slice_in_dim(
                b, y.astype(b.dtype), ci * chunk_len, 0), ys_buf, ys)
        return ci + 1, carry, ys_buf

    def cond(loop):
        ci, carry, _ = loop
        return (ci < n_chunks) & cont_fn(carry)

    ci, carry, ys = jax.lax.while_loop(
        cond, bundle, (jnp.int32(0), init_carry, ys_init))
    return carry, ys, ci


def fame1_wrap(step_fn: Callable):
    """f(state, x) -> (state, y)  ==>  h((state,), (x, valid)) which holds
    state and emits an invalid token when `valid` is False."""

    def host_step(state, inp):
        x, valid = inp
        new_state, y = step_fn(state, x)
        state = _select_tree(valid, new_state, state)
        return state, (y, valid)

    return host_step


def run_hosted(step_fn, init_state, tokens, valid_mask):
    """Run `step_fn` under a host schedule.

    tokens: (H, ...) per-host-cycle input (entries where valid_mask is
    False are ignored); valid_mask: (H,) bool.  Returns (final_state,
    outputs (T, ...)) where T = number of valid host cycles — i.e. the
    *target*-cycle view, independent of the stall pattern.
    """
    hosted = fame1_wrap(step_fn)
    final, (ys, valids) = jax.lax.scan(hosted, init_state,
                                       (tokens, valid_mask))
    # compact to target cycles: stable order of the valid outputs
    order = jnp.argsort(~valids, stable=True)
    n_valid = jnp.sum(valids)
    compacted = jax.tree.map(lambda y: y[order], ys)
    return final, compacted, n_valid


@dataclasses.dataclass
class Component:
    """A FAME-1-transformed target component."""
    name: str
    step_fn: Callable                    # (state, x) -> (state, y)
    init_state: Any
    init_output: Any                     # token value emitted before any input


class FAME1Pipeline:
    """Chain of components with single-slot token channels between them.

    Each host cycle: component i fires iff its input channel holds a token
    and its output channel is empty (downstream consumed).  An external
    stall pattern may additionally gate any component — simulating host
    non-determinism (DRAM delays, FPGA stalls).  Target behaviour is
    invariant to that pattern (the FAME-1 guarantee).
    """

    def __init__(self, components: list[Component]):
        self.components = components
        self.last_host_cycles: int | None = None   # set by run(), for perf
                                                   # accounting/benchmarks
        # jit once per pipeline: repeated run() calls with the same shapes
        # reuse the compiled host program instead of retracing (the seed
        # rebuilt its scan closure per call, so nothing ever cached).
        self._fixed_prog = jax.jit(self._fixed_impl)
        self._chunked_prog = jax.jit(self._chunked_impl)

    # -- host program ------------------------------------------------------
    def _init_carry(self, inputs, t_total):
        comp_states = tuple(c.init_state for c in self.components)
        # channel i feeds component i; channel n collects the sink.
        # channel 0 carries SOURCE tokens: initialise from the input type.
        chan_vals = (jax.tree.map(lambda a: jnp.zeros_like(a[0]), inputs),
                     ) + tuple(c.init_output for c in self.components)
        chan_full = jnp.zeros((len(self.components) + 1,), bool)
        out_buf = jax.tree.map(
            lambda y: jnp.zeros((t_total,) + jnp.shape(y),
                                jnp.result_type(y)),
            self.components[-1].init_output)
        return (comp_states, chan_vals, chan_full,
                jnp.int32(0), jnp.int32(0), out_buf)

    def _host_cycle_fn(self, inputs, t_total):
        n = len(self.components)

        def host_cycle(carry, inp):
            stalls, active = inp
            states, chans, full, src_idx, out_idx, outs = carry
            # source: push next input token into channel 0 when empty
            can_push = active & (~full[0]) & (src_idx < t_total)
            tok = jax.tree.map(lambda a: a[jnp.minimum(src_idx, t_total - 1)],
                               inputs)
            chans = (_select_tree(can_push, tok, chans[0]),) + chans[1:]
            full = full.at[0].set(full[0] | can_push)
            src_idx = src_idx + can_push.astype(jnp.int32)

            new_states = []
            for i, comp in enumerate(self.components):
                fire = active & full[i] & (~full[i + 1]) & (~stalls[i])
                s_new, y = comp.step_fn(states[i], chans[i])
                new_states.append(_select_tree(fire, s_new, states[i]))
                chans = chans[: i + 1] + (
                    _select_tree(fire, y, chans[i + 1]),) + chans[i + 2:]
                full = full.at[i].set(full[i] & ~fire)
                full = full.at[i + 1].set(full[i + 1] | fire)
            # sink: drain channel n
            drain = active & full[n]
            outs = jax.tree.map(
                lambda buf, v: jax.lax.select(
                    drain,
                    jax.lax.dynamic_update_index_in_dim(
                        buf, jnp.expand_dims(v, 0).astype(buf.dtype),
                        jnp.minimum(out_idx, t_total - 1), 0),
                    buf),
                outs, chans[n])
            full = full.at[n].set(full[n] & ~drain)
            out_idx = out_idx + drain.astype(jnp.int32)
            return (tuple(new_states), chans, full, src_idx, out_idx, outs), None

        return host_cycle

    def _fixed_impl(self, inputs, host_stalls, active):
        t_total = jax.tree.leaves(inputs)[0].shape[0]
        (states, _, _, _, out_idx, outs), _ = jax.lax.scan(
            self._host_cycle_fn(inputs, t_total),
            self._init_carry(inputs, t_total), (host_stalls, active))
        return states, outs, out_idx

    def _chunked_impl(self, inputs, stalls_chunks, active_chunks):
        t_total = jax.tree.leaves(inputs)[0].shape[0]
        n_chunks = stalls_chunks.shape[0]
        host_cycle = self._host_cycle_fn(inputs, t_total)

        def cond(loop):
            ci, _, (_, _, _, _, out_idx, _) = loop
            return (ci < n_chunks) & (out_idx < t_total)

        def body(loop):
            ci, cycles, inner = loop
            inner, _ = jax.lax.scan(
                host_cycle, inner, (stalls_chunks[ci], active_chunks[ci]))
            return (ci + 1,
                    cycles + jnp.sum(active_chunks[ci], dtype=jnp.int32),
                    inner)

        _, cycles, (states, _, _, _, out_idx, outs) = jax.lax.while_loop(
            cond, body, (jnp.int32(0), jnp.int32(0),
                         self._init_carry(inputs, t_total)))
        return states, outs, out_idx, cycles

    # -- public API --------------------------------------------------------
    def run(self, inputs, host_stalls=None, max_host_cycles: int | None = None,
            *, early_exit: bool = True, chunk_cycles: int = 64):
        """inputs: (T, ...) source tokens.  host_stalls: (H, n_components)
        bool — True = stall that component that cycle.

        With ``early_exit`` (default) the schedule is first compacted —
        all-stall host cycles are dropped, since source push and sink
        drain are retried identically on the next cycle — and then
        replayed in ``chunk_cycles``-sized scans under a
        ``lax.while_loop`` that stops as soon as all T tokens have
        drained.  ``early_exit=False`` replays the fixed schedule
        exactly as given (the seed behaviour); both paths produce
        bit-identical target-visible results.
        """
        n = len(self.components)
        inputs = jax.tree.map(jnp.asarray, inputs)
        t_total = jax.tree.leaves(inputs)[0].shape[0]
        if host_stalls is None:
            h_total = max_host_cycles or (4 * t_total * (n + 1))
            host_stalls = jnp.zeros((h_total, n), bool)
        else:
            host_stalls = jnp.asarray(host_stalls, bool)
            if early_exit:
                # pre-compaction: an all-stall cycle cannot change target
                # -visible behaviour (FAME-1 invariance), so skip it
                host_stalls = host_stalls[~jnp.all(host_stalls, axis=1)]
        h_total = host_stalls.shape[0]

        if not early_exit:
            states, outs, out_idx = self._fixed_prog(
                inputs, host_stalls, jnp.ones((h_total,), bool))
            self.last_host_cycles = h_total
            return states, outs, out_idx

        # chunked replay with early exit once the sink has drained; the
        # chunk count is bucketed to a power of two so schedules of
        # similar length share one compiled program (inactive padding
        # cycles are masked out and skipped by the early exit).
        n_chunks = 1 << max(0, -(-h_total // chunk_cycles) - 1).bit_length()
        pad = n_chunks * chunk_cycles - h_total
        stalls_chunks = jnp.concatenate(
            [host_stalls, jnp.zeros((pad, n), bool)]).reshape(
            n_chunks, chunk_cycles, n)
        active_chunks = (jnp.arange(n_chunks * chunk_cycles)
                         < h_total).reshape(n_chunks, chunk_cycles)
        states, outs, out_idx, cycles = self._chunked_prog(
            inputs, stalls_chunks, active_chunks)
        self.last_host_cycles = int(cycles)
        return states, outs, out_idx
