"""Multi-node SoC farm: N accelerator nodes behind one token-routed NoC.

FireSim scales past one FPGA by connecting simulated nodes through a
cycle-token switch; this module is that farm for the paper's SoC model.
A *victim* node (an NVDLA or NPU trace compiler's DBB stream, chunked
into requests) and ``nodes`` bandwidth co-runner nodes all target one
shared memory port of a ``repro.core.noc`` switch, and the shared
LLC/DRAM behind that port is the interference lane of
``repro.core.sweep`` — so one farm simulation composes the two exact
halves of a request's latency:

* **interconnect** — the victim's per-request flit latency through the
  switch (queueing behind co-runner flits + link latency), cycle-exact
  under deterministic round-robin arbitration and FAME-1 token-bundle
  execution;
* **memory** — the per-request (per-chunk) LLC/DRAM service latency
  from ``lane_request_latencies``, with the co-runners' write streams
  physically interleaved into the victim's trace, optionally under an
  LLC way partition (``way_mask``) that fences the victim's ways off
  from co-runner allocation.

The victim injects one flit per request every ``victim_gap`` cycles
(offered load ``1 / victim_gap``); each co-runner node injects every
``corunner_gap`` cycles.  The memory egress moves one flit per cycle,
so total offered load beyond 1.0 saturates it and victim queueing grows
through the window — the mechanism behind the superlinear p99 tail
``benchmarks/fig6_tail.py`` measures.  Way partitioning recovers the
*memory* half of the tail (protected LLC ways keep the victim's
cross-pass reuse); the interconnect half is policy-free contention.

``passes=2`` (the default) replays the victim window twice so the
second pass measures steady-state reuse — the serving-engine view,
where a decode step re-references the working set the previous step
left in the LLC.  ``FarmResult.steady`` slices the per-request arrays
to that final pass.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cache import LLCConfig
from repro.core.noc import NoCConfig, NoCResult, NoCSwitch
from repro.core.sweep import LaneMetrics, MixConfig, lane_request_latencies


@dataclasses.dataclass(frozen=True)
class FarmConfig:
    """Farm topology and injection timing (target cycles).

    ``nodes`` co-runner nodes ride beside the victim; the switch has
    ``nodes + 2`` ports (victim, co-runners, memory).  ``way_mask``
    (victim LLC allocation mask, ``None`` = unpartitioned) is the QoS
    knob under test."""
    nodes: int = 1
    link_latency: int = 4
    victim_gap: int = 2
    corunner_gap: int = 1
    bundle_cycles: int = 64
    passes: int = 2
    way_mask: int | None = None
    wss: str = "llc"

    def __post_init__(self):
        if self.nodes < 0:
            raise ValueError(f"nodes must be >= 0, got {self.nodes}")
        if self.victim_gap < 1 or self.corunner_gap < 1:
            raise ValueError("injection gaps must be >= 1 cycle")
        if self.passes < 1:
            raise ValueError(f"passes must be >= 1, got {self.passes}")


@dataclasses.dataclass(frozen=True)
class FarmResult:
    """Per-victim-request latency decomposition, request order == the
    victim's chunk order.  ``total = noc + memory`` elementwise."""
    noc_latency: np.ndarray      # (R,) int64 switch queueing + link
    mem_latency: np.ndarray      # (R,) int64 LLC/DRAM service cycles
    total_latency: np.ndarray    # (R,) int64
    metrics: LaneMetrics         # the lane's aggregate memory record
    noc: NoCResult               # the full switch delivery log
    requests: int                # victim requests (all passes)
    passes: int

    def steady(self) -> np.ndarray:
        """Total latencies of the final victim pass — the steady-state
        (warmed-LLC) distribution the QoS suite summarizes."""
        per_pass = self.requests // self.passes
        return self.total_latency[self.requests - per_pass:]


def victim_window(backend: str = "nvdla", *, max_bursts: int = 4096,
                  chunk_bursts: int = 16) -> list:
    """The victim node's DBB window from either trace compiler — the
    NVDLA register-level stream or the NPU systolic-array stream, both
    chunk-aligned so one chunk is one farm request."""
    if backend == "nvdla":
        from repro.core import traces

        return traces.default_dbb_window(max_bursts=max_bursts,
                                         chunk_bursts=chunk_bursts)
    if backend == "npu":
        from repro.core import npu

        return npu.default_npu_window(max_bursts=max_bursts,
                                      chunk_bursts=chunk_bursts)
    raise ValueError(f"unknown victim backend {backend!r} "
                     "(expected 'nvdla' or 'npu')")


def farm_schedule(requests: int, farm: FarmConfig) -> np.ndarray:
    """The switch injection schedule: (T, nodes + 2) egress indices,
    -1 for no-flit cycles.  Victim = port 0, co-runners = ports
    1..nodes, memory egress = port nodes + 1.  The victim injects its
    ``requests`` flits every ``victim_gap`` cycles; each co-runner
    injects every ``corunner_gap`` cycles across that whole window."""
    ports = farm.nodes + 2
    mem = ports - 1
    horizon = max(1, requests * farm.victim_gap)
    dests = np.full((horizon, ports), -1, np.int64)
    dests[np.arange(requests) * farm.victim_gap, 0] = mem
    for w in range(farm.nodes):
        dests[np.arange(0, horizon, farm.corunner_gap), 1 + w] = mem
    return dests


def simulate_farm(nvdla_segs: list | None = None, *, llc: LLCConfig,
                  dram, farm: FarmConfig | None = None,
                  chunk_bursts: int = 16, t_llc_hit: int = 20,
                  backend: str = "nvdla",
                  max_bursts: int = 2048) -> FarmResult:
    """One farm simulation: victim requests through the NoC switch and
    the shared memory system, composed into per-request latencies.

    ``nvdla_segs`` is ONE victim pass (defaults to the chosen
    ``backend``'s window clipped to ``max_bursts``); the lane replays
    it ``farm.passes`` times so later passes see the LLC the earlier
    ones warmed.  The memory lane's co-runner count equals the farm's
    node count — the same cores contend on both the switch and the
    cache."""
    farm = farm or FarmConfig()
    if nvdla_segs is None:
        nvdla_segs = victim_window(backend, max_bursts=max_bursts,
                                   chunk_bursts=chunk_bursts)
    lane_segs = list(nvdla_segs) * farm.passes
    mix = MixConfig(corunners=farm.nodes,
                    wss=farm.wss if farm.nodes else "l1")
    mem_lat, metrics = lane_request_latencies(
        lane_segs, llc=llc, dram=dram, mix=mix,
        chunk_bursts=chunk_bursts, t_llc_hit=t_llc_hit,
        way_mask=farm.way_mask)
    requests = int(mem_lat.shape[0])
    sched = farm_schedule(requests, farm)
    switch = NoCSwitch(NoCConfig(ports=farm.nodes + 2,
                                 link_latency=farm.link_latency))
    noc = switch.simulate(sched, bundle_cycles=farm.bundle_cycles)
    noc_lat = noc.source_latencies(0)
    if noc_lat.shape[0] != requests:
        raise RuntimeError(
            f"switch delivered {noc_lat.shape[0]} victim flits for "
            f"{requests} requests — schedule/lane mismatch")
    mem_lat = np.asarray(mem_lat, np.int64)
    return FarmResult(noc_latency=noc_lat, mem_latency=mem_lat,
                      total_latency=noc_lat + mem_lat, metrics=metrics,
                      noc=noc, requests=requests, passes=farm.passes)
