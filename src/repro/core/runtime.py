"""Command-stream compiler: network descriptor -> accelerator/CPU ops.

NVDLA is driven by a command stream written over CSB: each hardware layer
is a descriptor naming operands (DRAM addresses), tiling, and the
post-processing chain; unsupported layers fall back to the host.  This
module is that compiler for our SoC model: it walks the YOLOv3 layer
table, assigns DBB addresses to every tensor, splits conv layers into
conv-buffer-sized tile passes, and emits:

* ``AccelOp`` — conv/shortcut descriptors with per-stream DBB traffic
  (weight / ifmap / ofmap bytes, burst-aligned) and MAC counts;
* ``CpuOp``   — upsample / route / yolo layers plus the fp32<->int8
  boundary conversions (counted element-wise, they run on the cores).

The tiling rule mirrors nv_large's operation: hold the smaller of
(weights, ifmap tile) resident in the 512 KiB conv buffer and stream the
other; when neither fits, the ifmap is tiled and the full weight set is
re-streamed once per tile — this is what makes some layers' weight
traffic a multiple of the weight bytes, and is exactly the spatial-
locality-heavy access pattern whose LLC behaviour the paper measures.
"""
from __future__ import annotations

import dataclasses

from repro.core import yolov3
from repro.core.yolov3 import Layer, accelerated


@dataclasses.dataclass(frozen=True)
class AccelOp:
    layer: Layer
    macs: int
    weight_traffic: int        # bytes read over DBB
    ifmap_traffic: int
    ofmap_traffic: int
    weight_passes: int         # how many times the weight set streams
    prev_ofmap_bytes: int      # producer's output (for LLC residency reuse)

    @property
    def read_traffic(self) -> int:
        return self.weight_traffic + self.ifmap_traffic

    @property
    def total_traffic(self) -> int:
        return self.read_traffic + self.ofmap_traffic


@dataclasses.dataclass(frozen=True)
class CpuOp:
    layer: Layer
    kind: str                  # upsample | route | yolo | cast
    elements: int              # elementwise work items
    bytes_moved: int


@dataclasses.dataclass(frozen=True)
class CommandStream:
    accel_ops: tuple
    cpu_ops: tuple

    @property
    def total_macs(self) -> int:
        return sum(op.macs for op in self.accel_ops)

    @property
    def accel_traffic(self) -> int:
        return sum(op.total_traffic for op in self.accel_ops)


def _tile_conv(l: Layer, conv_buf_bytes: int) -> tuple[int, int, int]:
    """Returns (weight_traffic, ifmap_traffic, weight_passes)."""
    wt, ifm = l.weight_bytes, l.ifmap_bytes
    half = conv_buf_bytes // 2
    if wt <= half or ifm <= half:
        # one operand resident -> both stream exactly once
        return wt, ifm, 1
    # neither fits: tile the ifmap into half-buffer chunks, re-stream the
    # full weight set per tile (NVDLA kernel-group iteration)
    n_tiles = -(-ifm // half)
    return wt * n_tiles, ifm, n_tiles


def compile_network(layers=None, *, conv_buf_bytes: int = 512 * 1024
                    ) -> CommandStream:
    layers = layers if layers is not None else yolov3.LAYERS
    accel_ops: list[AccelOp] = []
    cpu_ops: list[CpuOp] = []
    prev_of = 0
    on_accel_prev = False

    for l in layers:
        if accelerated(l):
            if l.kind == "conv":
                wt_t, if_t, passes = _tile_conv(l, conv_buf_bytes)
                macs = l.macs
            else:  # shortcut: SDP elementwise add, reads two maps
                wt_t, if_t, passes, macs = 0, 2 * l.ifmap_bytes, 1, 0
            if not on_accel_prev and l.index > 0:
                # fp32 -> int8 boundary conversion on the CPU
                cpu_ops.append(CpuOp(l, "cast", l.ifmap_bytes,
                                     5 * l.ifmap_bytes))
            accel_ops.append(AccelOp(
                layer=l, macs=macs, weight_traffic=wt_t, ifmap_traffic=if_t,
                ofmap_traffic=l.ofmap_bytes, weight_passes=passes,
                prev_ofmap_bytes=prev_of))
            on_accel_prev = True
        else:
            if on_accel_prev:
                # int8 -> fp32 conversion of the accelerator's output
                cpu_ops.append(CpuOp(l, "cast", l.ifmap_bytes,
                                     5 * l.ifmap_bytes))
            elems = l.out_h * l.out_w * l.cout
            cpu_ops.append(CpuOp(l, l.kind, elems,
                                 l.ifmap_bytes + 4 * elems))
            on_accel_prev = False
        prev_of = l.ofmap_bytes
    return CommandStream(tuple(accel_ops), tuple(cpu_ops))
