"""Paged KV cache: fixed-size token blocks, free-list pool, block tables.

The continuous-batching engine accounts KV capacity the way vLLM does —
a shared pool of fixed-size blocks, a per-request block table — but the
blocks here are *simulated DBB address ranges*, not device memory: the
jitted decode kernel keeps its shape-static per-slot cache rows, while
the pool decides admission (are there blocks for prompt + max_new?) and
hands the latency oracle the exact byte ranges a request re-reads each
step.  That address map is what makes concurrent requests contend in the
shared LLC: each admitted request adds its live blocks to the per-step
trace, growing the cyclic re-reference distance until the cache stops
covering the working set (the paper's interference story).

Admission is reservation-based: all ``ceil((prompt + max_new) /
block_size)`` blocks are allocated up front, so a request can never be
starved mid-decode by a later admission (no preemption/swap path —
an engine-level future work note in docs/serving.md).

Invariant (hypothesis-tested): at any point in any admit/append/release
sequence, the free list and the union of all block tables form a
partition of the pool — every block exactly once, no aliasing.
"""
from __future__ import annotations

import dataclasses

from repro.core import traces

#: Base byte address of the paged-KV region in the simulated DBB map:
#: above the weight stream (from ``traces.WEIGHT_REGION`` = 0x0, capped
#: by the oracle at this base) and below the co-runner regions at
#: 0x4000_0000+.  The exact segment engine carries segment bases as
#: int32, so every serving region must stay under 2**31.
KV_REGION = 0x2000_0000

#: Recurrent/cross state region — one aligned span per slot, between
#: the KV pool and the co-runner regions.
STATE_REGION = 0x3800_0000


class OutOfBlocksError(RuntimeError):
    """The pool cannot cover a reservation — admission must wait."""


@dataclasses.dataclass(frozen=True)
class BlockTable:
    """One request's page mapping (immutable snapshot)."""
    rid: int
    block_ids: tuple[int, ...]
    tokens: int


class PagedKVCache:
    """Block pool + per-request block tables over a simulated region.

    ``token_bytes`` is the marginal KV bytes per decoded token
    (``DecodeWorkingSet.kv_token_bytes``); block byte spans are rounded
    up to the LLC block size (64 B) so segments stay burst-aligned.
    """

    def __init__(self, *, num_blocks: int, block_size: int,
                 token_bytes: int, region_base: int = KV_REGION):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)          # tokens per block
        self.token_bytes = max(1, int(token_bytes))
        raw = self.block_size * self.token_bytes
        self.block_bytes = -(-raw // 64) * 64      # burst/line aligned
        self.region_base = int(region_base)
        last = self.region_base + self.num_blocks * self.block_bytes
        if self.region_base == KV_REGION and last > STATE_REGION:
            raise ValueError(
                f"KV pool ({last:#x}) spans into the per-slot state "
                f"region at {STATE_REGION:#x}; shrink num_blocks or "
                "rebase the pool")
        if last >= 1 << 31:
            raise ValueError(
                f"KV pool end {last:#x} exceeds the segment engine's "
                "int32 address range; shrink num_blocks or rebase the "
                "region")
        # LIFO free list; pop() hands out the lowest ids first so fresh
        # pools produce deterministic, compact address maps.
        self._free: list[int] = list(range(self.num_blocks - 1, -1, -1))
        self._tables: dict[int, list[int]] = {}
        self._tokens: dict[int, int] = {}
        self._reserved: dict[int, int] = {}        # rid -> blocks reserved

    # -- capacity ----------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for(self, tokens: int) -> int:
        return -(-max(0, int(tokens)) // self.block_size)

    def can_admit(self, total_tokens: int) -> bool:
        return self.blocks_for(total_tokens) <= self.free_blocks

    # -- lifecycle ---------------------------------------------------------
    def admit(self, rid: int, prompt_tokens: int, max_new: int) -> BlockTable:
        """Reserve every block the request can ever touch and record its
        prompt as written.  Raises ``OutOfBlocksError`` if the pool
        cannot cover the reservation, ``ValueError`` on a duplicate rid.
        """
        if rid in self._tables:
            raise ValueError(f"request {rid} already admitted")
        if prompt_tokens <= 0:
            raise ValueError("prompt must be at least one token")
        need = self.blocks_for(prompt_tokens + max(0, max_new))
        if need > self.free_blocks:
            raise OutOfBlocksError(
                f"request {rid} needs {need} blocks, pool has "
                f"{self.free_blocks} free")
        self._tables[rid] = [self._free.pop() for _ in range(need)]
        self._tokens[rid] = int(prompt_tokens)
        self._reserved[rid] = need
        return self.table(rid)

    def append(self, rid: int, n: int = 1) -> BlockTable:
        """Record n decoded tokens written into the reservation."""
        if rid not in self._tables:
            raise KeyError(f"request {rid} not admitted")
        t = self._tokens[rid] + int(n)
        if self.blocks_for(t) > self._reserved[rid]:
            raise OutOfBlocksError(
                f"request {rid} wrote past its reservation "
                f"({t} tokens > {self._reserved[rid]} blocks)")
        self._tokens[rid] = t
        return self.table(rid)

    def release(self, rid: int) -> None:
        """Return every block of a finished request to the free list."""
        blocks = self._tables.pop(rid)
        del self._tokens[rid], self._reserved[rid]
        self._free.extend(reversed(blocks))   # LIFO: reuse hottest first

    # -- views -------------------------------------------------------------
    def table(self, rid: int) -> BlockTable:
        return BlockTable(rid=rid, block_ids=tuple(self._tables[rid]),
                          tokens=self._tokens[rid])

    def live_requests(self) -> tuple[int, ...]:
        return tuple(sorted(self._tables))

    def block_address(self, block_id: int) -> int:
        return self.region_base + int(block_id) * self.block_bytes

    def read_segments(self, rid: int, *, tokens: int | None = None) -> list:
        """Compressed DBB read segments covering the request's written
        tokens (one 32 B-burst run per block; the last block partial).
        ``tokens`` caps the read below the written length (windowed
        working sets)."""
        written = self._tokens[rid]
        t = written if tokens is None else min(int(tokens), written)
        segs = []
        left = t
        for bid in self._tables[rid]:
            if left <= 0:
                break
            in_block = min(left, self.block_size)
            n_bytes = in_block * self.token_bytes
            segs.append(traces.Segment(
                self.block_address(bid), traces.BURST_BYTES,
                -(-n_bytes // traces.BURST_BYTES), f"kv{rid}"))
            left -= in_block
        return segs

    # -- invariants --------------------------------------------------------
    def check_partition(self) -> None:
        """Free list ∪ block tables must partition [0, num_blocks) with
        no block appearing twice (the hypothesis-tested invariant)."""
        seen: dict[int, str] = {}
        for b in self._free:
            if b in seen:
                raise AssertionError(f"block {b} twice in free list")
            seen[b] = "free"
        for rid, blocks in self._tables.items():
            for b in blocks:
                if b in seen:
                    raise AssertionError(
                        f"block {b} aliased: {seen[b]} and request {rid}")
                seen[b] = f"req{rid}"
        if len(seen) != self.num_blocks:
            missing = set(range(self.num_blocks)) - set(seen)
            raise AssertionError(f"blocks leaked: {sorted(missing)[:8]}")

    # -- checkpoint --------------------------------------------------------
    def snapshot(self) -> dict:
        return {"free": list(self._free),
                "tables": {r: list(b) for r, b in self._tables.items()},
                "tokens": dict(self._tokens),
                "reserved": dict(self._reserved)}

    def restore(self, snap: dict) -> None:
        self._free = [int(b) for b in snap["free"]]
        self._tables = {int(r): [int(b) for b in bs]
                        for r, bs in snap["tables"].items()}
        self._tokens = {int(r): int(t) for r, t in snap["tokens"].items()}
        self._reserved = {int(r): int(n)
                          for r, n in snap["reserved"].items()}
        self.check_partition()
