"""Serving: continuous batching scheduled by simulated SoC latencies."""
from repro.serve.engine import (  # noqa: F401
    EngineStats,
    GenerationResult,
    Request,
    ServeEngine,
    StepResult,
)
from repro.serve.kvcache import (  # noqa: F401
    BlockTable,
    OutOfBlocksError,
    PagedKVCache,
)
from repro.serve.oracle import SoCLatencyOracle, StepLatency  # noqa: F401
