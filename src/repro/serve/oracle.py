"""SoC latency oracle: serving steps -> DBB traces -> simulated cycles.

This is where the serving engine closes the loop with the paper's memory
system.  Each scheduler step is lowered to a compressed DBB segment
trace from the model's decode working set (``models.decode_working_set``):

* a weight stream from ``traces.WEIGHT_REGION`` — every active parameter
  read once per decoded token;
* per-slot KV reads over the request's paged blocks (``PagedKVCache``
  addresses), plus a constant recurrent/cross-state read per slot;
* optional BwWrite co-runner lanes (``MixConfig``), the paper's Fig. 6
  interference cores, interleaved at arbiter-chunk granularity.

Decode steps are charged their *steady-state marginal* cost: the step
trace is its own warm prefix (``sweep.step_lane_metrics(...,
warm_prefix=step)``), so working sets that fit the LLC re-hit across
steps and each admitted co-resident request grows the cyclic
re-reference distance — occupancy degrades hit rate exactly the way
Fig. 6's co-runners do, and the tail of the latency distribution
inherits it.  Prefill steps are charged cold (first touch of new
blocks).

Cycles convert to seconds at the SoC clock (the paper's 3.2 GHz FireSim
config); results are memoized by the exact trace signature, so a steady
occupancy pattern costs one simulation.
"""
from __future__ import annotations

import dataclasses

from repro.core import traces
from repro.core.cache import LLCConfig
from repro.core.dram import DRAMConfig
from repro.core.sweep import LaneMetrics, MixConfig, step_lane_metrics
from repro.serve.kvcache import KV_REGION, STATE_REGION, PagedKVCache

SOC_FREQ_HZ = 3.2e9      # shared CPU/NVDLA clock in the paper's config

# every accelerator backend the oracle can lower a step's weight stream
# for — benchmarks/serve_bench.py sweeps all of them, and
# tests/test_serve_bench.py pins that coverage
SUPPORTED_BACKENDS = ("nvdla", "npu")


@dataclasses.dataclass(frozen=True)
class StepLatency:
    """One scheduler step's simulated cost."""
    cycles: int
    seconds: float
    metrics: LaneMetrics


class SoCLatencyOracle:
    """Maps a serving step's working set to simulated SoC latency.

    Keyword-only configuration, matching the sweep APIs: ``llc=``,
    ``dram=``, ``mix=`` (co-runner interference), ``chunk_bursts=`` (the
    DBB arbiter granularity between the weight stream, each slot's KV
    stream, and co-runner lanes), ``weight_bytes=`` overriding the
    model-derived stream footprint (benchmarks use it to place the
    working set relative to LLC capacity).

    ``backend="npu"`` swaps the weight stream's shape: instead of
    NVDLA's single sequential parameter read, the step fetches weights
    the way the systolic array's weight-stationary schedule would —
    per-stripe segments from ``repro.core.npu.decode_weight_segments``,
    re-streamed when a stripe outgrows the weight SRAM while the decode
    batch spans multiple m tiles (``npu=`` sizes the array).  KV/state
    streams and all costing are backend-independent.
    """

    def __init__(self, working_set, *, llc: LLCConfig | None = None,
                 dram: DRAMConfig | None = None,
                 mix: MixConfig | None = None,
                 chunk_bursts: int = 256, t_llc_hit: int = 20,
                 freq_hz: float = SOC_FREQ_HZ,
                 weight_bytes: int | None = None,
                 backend: str = "nvdla", npu=None):
        if backend not in SUPPORTED_BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; the oracle "
                             f"speaks {', '.join(SUPPORTED_BACKENDS)}")
        if npu is not None and backend != "npu":
            raise ValueError("npu= only applies to backend='npu'")
        self.ws = working_set
        self.llc = llc or LLCConfig()
        self.dram = dram or DRAMConfig()
        self.mix = mix or MixConfig()
        self.chunk_bursts = int(chunk_bursts)
        self.t_llc_hit = int(t_llc_hit)
        self.freq_hz = float(freq_hz)
        self.backend = backend
        if backend == "npu":
            from repro.core.npu import NPUConfig

            self.npu = npu or NPUConfig()
        else:
            self.npu = None
        self.weight_bytes = int(weight_bytes if weight_bytes is not None
                                else working_set.weight_bytes)
        if self.weight_bytes >= KV_REGION:
            raise ValueError(
                f"weight stream ({self.weight_bytes:#x} bytes from "
                f"{traces.WEIGHT_REGION:#x}) would overlap the paged-KV "
                f"region at {KV_REGION:#x}; pass weight_bytes= to model "
                "a resident subset")
        self._memo: dict = {}
        self._wseg_memo: dict = {}

    # -- trace construction ------------------------------------------------
    def _weight_segments(self, slots: int = 1) -> list:
        """The step's parameter-read stream (all segments labeled
        ``weight``, so the arbiter treats them as one lane).  NVDLA
        reads the heap as one sequential burst run; the NPU fetches
        per-stripe under its weight-stationary schedule, which depends
        on the decode batch width (``slots``) — memoized per width."""
        segs = self._wseg_memo.get(slots)
        if segs is None:
            if self.backend == "npu":
                from repro.core import npu as npu_mod

                segs = npu_mod.decode_weight_segments(
                    self.weight_bytes, self.npu, m=max(1, slots))
                end = max(s.base + s.stride * s.count for s in segs)
                if end > KV_REGION:
                    raise ValueError(
                        f"NPU weight stripes (padded to {end:#x}) overlap "
                        f"the paged-KV region at {KV_REGION:#x}; pass a "
                        "smaller weight_bytes=")
            else:
                segs = [traces.Segment(
                    traces.WEIGHT_REGION, traces.BURST_BYTES,
                    -(-self.weight_bytes // traces.BURST_BYTES), "weight")]
            self._wseg_memo[slots] = segs
        return segs

    def _state_segment(self, slot: int) -> traces.Segment | None:
        if not self.ws.state_bytes:
            return None
        span = -(-self.ws.state_bytes // 64) * 64
        base = STATE_REGION + slot * span
        if base + span > 0x4000_0000:
            raise ValueError(
                f"slot {slot} state span ({span:#x} bytes) runs past the "
                "co-runner regions at 0x4000_0000; shrink max_slots or "
                "the recurrent state")
        return traces.Segment(base, traces.BURST_BYTES,
                              -(-self.ws.state_bytes // traces.BURST_BYTES),
                              f"state{slot}")

    def decode_trace(self, kv: PagedKVCache, rids: list[int]) -> list:
        """One decode step's interleaved read trace at the current
        occupancy: the weight stream round-robined against each active
        request's live KV + state reads at arbiter-chunk granularity."""
        streams: list = list(self._weight_segments(len(rids)))
        for slot, rid in enumerate(rids):
            live = self.ws.kv_bytes(kv.table(rid).tokens)
            tokens_live = (live // max(1, self.ws.kv_token_bytes)
                           if self.ws.kv_token_bytes else 0)
            streams.extend(kv.read_segments(rid, tokens=tokens_live))
            st = self._state_segment(slot)
            if st is not None:
                streams.append(st)
        return traces.interleave(streams, chunk_bursts=self.chunk_bursts)

    def prefill_trace(self, kv: PagedKVCache, rids: list[int]) -> list:
        """Prefill writes the admitted prompts' blocks once (plus one
        weight stream for the prompt pass)."""
        streams: list = list(self._weight_segments(len(rids)))
        for rid in rids:
            streams.extend(kv.read_segments(rid))
        return traces.interleave(streams, chunk_bursts=self.chunk_bursts)

    # -- costing -----------------------------------------------------------
    def _cost(self, trace: list, *, steady: bool) -> StepLatency:
        key = (steady, tuple(traces.segment_tuple(s) for s in trace))
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        m = step_lane_metrics(
            trace, llc=self.llc, dram=self.dram, mix=self.mix,
            warm_prefix=(trace if steady else None),
            chunk_bursts=self.chunk_bursts, t_llc_hit=self.t_llc_hit)
        out = StepLatency(cycles=m.total_cycles,
                          seconds=m.total_cycles / self.freq_hz, metrics=m)
        self._memo[key] = out
        return out

    def decode_step(self, kv: PagedKVCache, rids: list[int]) -> StepLatency:
        """Steady-state marginal cost of one decode step at the current
        slot occupancy."""
        return self._cost(self.decode_trace(kv, rids), steady=True)

    def prefill_step(self, kv: PagedKVCache, rids: list[int],
                     decode_rids: list[int] = ()) -> StepLatency:
        """Cold cost of admitting ``rids`` (prompt block fill).  When
        the engine runs prefill and decode in the same step
        (disaggregation), the decoding slots' reads join the trace so
        admission contends with in-flight requests."""
        streams = self.prefill_trace(kv, rids)
        if decode_rids:
            streams = streams + self.decode_trace(kv, list(decode_rids))
        return self._cost(streams, steady=False)
