"""Batched serving engine: prefill + greedy/temperature decode.

A deliberately small but production-shaped engine:

* requests are padded to a common prompt length and batched;
* one jitted ``prefill`` fills the caches, then a jitted ``decode_step``
  runs autoregressively (the step function is compiled once and reused —
  cache shapes are static);
* EOS handling masks finished rows (their tokens freeze), so a batch with
  heterogeneous completion lengths costs one kernel per step regardless.

The multi-pod serving path is exercised by ``launch/dryrun.py`` which
lowers exactly this ``decode_step`` for the decode/long-context cells.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, prefill


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_new) generated ids
    lengths: np.ndarray         # (B,) #tokens before EOS (or max_new)
    steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, cache_len: int,
                 eos_id: int = 2, temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.temperature = temperature
        self._prefill = jax.jit(
            functools.partial(prefill, cfg=cfg, cache_len=cache_len))
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        v = self.cfg.vocab_size
        logits = logits[:, :v] if logits.shape[-1] != v else logits
        if self.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature).astype(jnp.int32)

    def generate(self, batch: dict, max_new: int, *, seed: int = 0
                 ) -> GenerationResult:
        """batch: {"tokens": (B, S) int32, + frames/patches stubs}."""
        b = batch["tokens"].shape[0]
        logits, caches, t = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        done = jnp.zeros((b,), bool)
        out = []
        tok = self._sample(logits, key)
        for i in range(max_new):
            tok = jnp.where(done, self.eos_id, tok)
            out.append(tok)
            done = done | (tok == self.eos_id)
            if bool(jnp.all(done)):
                break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, caches, tok[:, None], t)
            t = t + 1
            tok = self._sample(logits, sub)
        toks = np.stack([np.asarray(o) for o in out], axis=1)
        lengths = np.argmax(toks == self.eos_id, axis=1)
        lengths = np.where((toks == self.eos_id).any(axis=1), lengths, toks.shape[1])
        return GenerationResult(tokens=toks, lengths=lengths, steps=len(out))
