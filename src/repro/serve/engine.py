"""Continuous-batching serving engine scheduled by simulated SoC latency.

The engine is production-shaped where it matters for the paper's story
and honest about being a simulator everywhere else:

* **continuous batching** — requests queue with arrival times and are
  admitted into per-request *slots* as capacity frees; the jitted decode
  kernel (``models.slot_decode_step``) advances every active slot in one
  call with an independent position per row, so sequences at different
  lengths batch without padding to a common step count;
* **paged KV cache** — a shared block pool with per-request block tables
  (``serve.kvcache``) governs admission and maps each request's KV to
  simulated DBB addresses.  The jitted kernel itself keeps shape-static
  per-slot cache rows (this is a serving *simulator*: the pool models
  capacity and memory traffic, not device paging);
* **prefill/decode disaggregation** — one scheduler step admits new
  requests (batched prefill fills their blocks) while the decode kernel
  advances the already-active slots; both working sets share the step's
  DBB trace so admission contends with in-flight requests;
* **simulated clock** — every step's latency comes from the SoC memory
  pipeline (``serve.oracle`` -> ``sweep.step_lane_metrics`` ->
  ``socsim.simulate_dbb_segments`` physics), so tokens/s and per-request
  p50/p99 are reported in simulated SoC time and LLC contention from
  slot occupancy shows up in the serving tail (the Fig. 6 effect).

Typed frozen ``Request`` / ``StepResult`` / ``EngineStats`` records with
``to_record()``/``from_record()`` are the journal currency, mirroring
``sweep.LaneMetrics``.  The seed's padded static-batch ``generate()``
survives as a deprecated shim that round-trips through the queue and
reproduces the seed's greedy tokens exactly (tests/test_serve.py).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import (
    cache_slot_axes,
    decode_working_set,
    init_caches,
    prefill,
    slot_decode_step,
)
from repro.serve.kvcache import PagedKVCache
from repro.serve.oracle import SoCLatencyOracle
from repro.types import param_values
from repro.utils.stats import nearest_rank


# --------------------------------------------------------------------------
# typed records
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: prompt tokens, a generation budget, and an
    offered-load arrival time (seconds, simulated clock)."""
    rid: int
    tokens: tuple[int, ...]
    max_new: int
    arrival_s: float = 0.0

    def __post_init__(self):
        if not self.tokens:
            raise ValueError("request needs at least one prompt token")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")

    def to_record(self) -> dict:
        return {"rid": self.rid, "tokens": list(self.tokens),
                "max_new": self.max_new, "arrival_s": self.arrival_s}

    @classmethod
    def from_record(cls, record: dict) -> "Request":
        return cls(rid=int(record["rid"]),
                   tokens=tuple(int(t) for t in record["tokens"]),
                   max_new=int(record["max_new"]),
                   arrival_s=float(record["arrival_s"]))


@dataclasses.dataclass(frozen=True)
class StepResult:
    """One scheduler step: what ran, what it emitted, and what the SoC
    pipeline charged for it."""
    step: int
    kind: str                       # "prefill" | "decode" | "mixed" | "idle"
    cycles: int
    sim_time_s: float               # clock *after* this step
    active: int                     # occupied slots during the step
    admitted: tuple[int, ...]       # rids admitted this step
    emitted: tuple[tuple[int, int], ...]   # (rid, token) pairs
    finished: tuple[int, ...]       # rids that completed this step
    llc_hit_rate: float | None = None      # None on idle steps

    _KINDS = ("prefill", "decode", "mixed", "idle")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown step kind {self.kind!r}")

    def to_record(self) -> dict:
        return {"step": self.step, "kind": self.kind, "cycles": self.cycles,
                "sim_time_s": self.sim_time_s, "active": self.active,
                "admitted": list(self.admitted),
                "emitted": [list(e) for e in self.emitted],
                "finished": list(self.finished),
                "llc_hit_rate": self.llc_hit_rate}

    @classmethod
    def from_record(cls, record: dict) -> "StepResult":
        hr = record["llc_hit_rate"]
        return cls(step=int(record["step"]), kind=str(record["kind"]),
                   cycles=int(record["cycles"]),
                   sim_time_s=float(record["sim_time_s"]),
                   active=int(record["active"]),
                   admitted=tuple(int(r) for r in record["admitted"]),
                   emitted=tuple((int(r), int(t))
                                 for r, t in record["emitted"]),
                   finished=tuple(int(r) for r in record["finished"]),
                   llc_hit_rate=None if hr is None else float(hr))


# Nearest-rank percentile — no interpolation, JSON/bit-stable.  The
# shared implementation lives in repro.utils.stats (the QoS benchmarks
# report the same statistic); the old inline version truncated q*n
# before the ceiling division, off by one for fractional q.
_nearest_rank = nearest_rank


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """End-of-run serving summary, all times in simulated SoC seconds."""
    requests: int
    tokens: int
    steps: int
    prefill_steps: int
    decode_steps: int
    idle_steps: int
    sim_time_s: float
    tokens_per_s: float
    latency_p50_s: float
    latency_p99_s: float
    mean_occupancy: float
    max_occupancy: int

    _INT_FIELDS = ("requests", "tokens", "steps", "prefill_steps",
                   "decode_steps", "idle_steps", "max_occupancy")
    _FLOAT_FIELDS = ("sim_time_s", "tokens_per_s", "latency_p50_s",
                     "latency_p99_s", "mean_occupancy")

    def to_record(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_record(cls, record: dict) -> "EngineStats":
        kw = {f: int(record[f]) for f in cls._INT_FIELDS}
        kw.update({f: float(record[f]) for f in cls._FLOAT_FIELDS})
        return cls(**kw)


@dataclasses.dataclass
class GenerationResult:
    """Result shape of the deprecated ``generate()`` shim (seed API)."""
    tokens: np.ndarray          # (B, steps) generated ids
    lengths: np.ndarray         # (B,) #tokens before EOS (or steps)
    steps: int


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Slot:
    rid: int
    t: int                       # absolute position of the next KV write
    last_token: int              # token the next decode consumes
    generated: list[int]
    max_new: int
    prompt_len: int
    arrival_s: float


class ServeEngine:
    """Continuous-batching engine over a model + simulated SoC.

    Constructor config is keyword-only: ``cache_len=`` (per-slot cache
    capacity; prompt + max_new must fit), ``block_size=`` (tokens per KV
    block), ``max_slots=`` (concurrent requests), ``oracle=`` (a
    ``SoCLatencyOracle``; default derives one from the model's decode
    working set), ``num_blocks=`` (pool size; default backs every slot
    at full cache_len), plus the seed's ``eos_id=``/``temperature=``.
    """

    def __init__(self, cfg: ModelConfig, params, *, cache_len: int,
                 block_size: int = 16, max_slots: int = 4,
                 oracle: SoCLatencyOracle | None = None,
                 num_blocks: int | None = None,
                 eos_id: int = 2, temperature: float = 0.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.cache_len = int(cache_len)
        self.block_size = int(block_size)
        self.max_slots = int(max_slots)
        self.eos_id = int(eos_id)
        self.temperature = float(temperature)
        self.seed = int(seed)
        ws = decode_working_set(cfg)
        self.oracle = oracle or SoCLatencyOracle(ws)
        if num_blocks is None:
            num_blocks = self.max_slots * -(-self.cache_len // self.block_size)
        self.kv = PagedKVCache(num_blocks=num_blocks,
                               block_size=self.block_size,
                               token_bytes=max(1, ws.kv_token_bytes))
        self._prefill = jax.jit(
            functools.partial(prefill, cfg=cfg, cache_len=self.cache_len))
        self._decode = jax.jit(
            functools.partial(slot_decode_step, cfg=cfg))
        self.queue: collections.deque = collections.deque()
        self._extras: dict[int, dict] = {}
        self.slots: list[_Slot | None] = [None] * self.max_slots
        self._caches = None          # lazy: materialized on first admission
        self._axes = None
        self.finished: list[dict] = []
        self.step_log: list[StepResult] = []
        self.clock_cycles = 0
        self.step_idx = 0
        self._counts = {"prefill": 0, "decode": 0, "mixed": 0, "idle": 0}
        self._occupancy_sum = 0
        self._occupancy_max = 0

    # -- submission --------------------------------------------------------
    @property
    def clock_s(self) -> float:
        return self.clock_cycles / self.oracle.freq_hz

    def submit(self, request: Request, *, extras: dict | None = None) -> None:
        """Queue a request.  ``extras`` carries non-token prefill inputs
        (e.g. whisper ``frames``), kept host-side — they are not part of
        the typed record."""
        total = len(request.tokens) + request.max_new
        if total > self.cache_len:
            raise ValueError(
                f"request {request.rid}: prompt {len(request.tokens)} + "
                f"max_new {request.max_new} exceeds cache_len "
                f"{self.cache_len}")
        if self.kv.blocks_for(total) > self.kv.num_blocks:
            raise ValueError(
                f"request {request.rid} needs "
                f"{self.kv.blocks_for(total)} KV blocks but the pool "
                f"only has {self.kv.num_blocks} — it could never be "
                "admitted")
        if any(r.rid == request.rid for r in self.queue) or any(
                s is not None and s.rid == request.rid for s in self.slots):
            raise ValueError(f"duplicate rid {request.rid}")
        self.queue.append(request)
        if extras:
            self._extras[request.rid] = {k: np.asarray(v)
                                         for k, v in extras.items()}

    # -- internals ---------------------------------------------------------
    def _materialize_caches(self) -> None:
        if self._caches is None:
            values = param_values(
                init_caches(self.cfg, self.max_slots, self.cache_len))
            self._caches = values
            self._axes = cache_slot_axes(values)

    def _request_key(self, rid: int, n: int):
        k = jax.random.fold_in(jax.random.PRNGKey(self.seed), rid)
        return jax.random.fold_in(k, n)

    def _sample_row(self, logits_row: np.ndarray, rid: int, n: int) -> int:
        v = self.cfg.vocab_size
        row = logits_row[:v]
        if self.temperature == 0.0:
            return int(np.argmax(row))
        return int(jax.random.categorical(
            self._request_key(rid, n),
            jnp.asarray(row) / self.temperature))

    def _free_slot_ids(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _active_slot_ids(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _admit(self) -> list[tuple[int, Request]]:
        """FIFO admission: arrival due, a free slot, and a full KV
        reservation available (head-of-line blocking by design — the
        trace replays deterministically)."""
        placed = []
        free = self._free_slot_ids()
        while (self.queue and free
               and self.queue[0].arrival_s <= self.clock_s
               and self.kv.can_admit(len(self.queue[0].tokens)
                                     + self.queue[0].max_new)):
            req = self.queue.popleft()
            slot_id = free.pop(0)
            self.kv.admit(req.rid, len(req.tokens), req.max_new)
            placed.append((slot_id, req))
        return placed

    def _run_prefill(self, placed: list[tuple[int, Request]]) -> list:
        """Batched prefill per same-length admission group; scatter the
        resulting rows into the slot caches; sample each request's first
        token (it counts against max_new, as in the seed loop)."""
        self._materialize_caches()
        emitted = []
        groups: dict[int, list[tuple[int, Request]]] = {}
        for slot_id, req in placed:
            groups.setdefault(len(req.tokens), []).append((slot_id, req))
        for plen, group in sorted(groups.items()):
            batch = {"tokens": jnp.asarray(
                [list(r.tokens) for _, r in group], jnp.int32)}
            ex = [self._extras.get(r.rid) for _, r in group]
            if ex[0] is not None:
                for k in ex[0]:
                    batch[k] = jnp.asarray(np.stack([e[k] for e in ex]))
            logits, new_caches, t_next = self._prefill(self.params, batch)
            sids = jnp.asarray([sid for sid, _ in group])
            self._caches = jax.tree_util.tree_map(
                lambda f, n, ax: (f.at[:, sids].set(n) if ax == 1
                                  else f.at[sids].set(n)),
                self._caches, new_caches, self._axes)
            logits_np = np.asarray(logits)
            for g, (slot_id, req) in enumerate(group):
                first = self._sample_row(logits_np[g], req.rid, 0)
                self.kv.append(req.rid)
                slot = _Slot(rid=req.rid, t=plen, last_token=first,
                             generated=[first], max_new=req.max_new,
                             prompt_len=plen, arrival_s=req.arrival_s)
                self.slots[slot_id] = slot
                emitted.append((req.rid, first))
        return emitted

    def _run_decode(self, slot_ids: list[int]) -> list:
        """One vmapped decode over the full slot batch; only the listed
        slots' rows are consumed (inactive rows compute garbage that the
        next prefill scatter overwrites)."""
        toks = np.zeros((self.max_slots, 1), np.int32)
        ts = np.zeros((self.max_slots,), np.int32)
        for i in slot_ids:
            s = self.slots[i]
            toks[i, 0] = s.last_token
            ts[i] = s.t
        logits, self._caches = self._decode(
            self.params, self._caches, jnp.asarray(toks), jnp.asarray(ts))
        logits_np = np.asarray(logits)
        emitted = []
        for i in slot_ids:
            s = self.slots[i]
            s.t += 1
            tok = self._sample_row(logits_np[i], s.rid, len(s.generated))
            s.generated.append(tok)
            s.last_token = tok
            self.kv.append(s.rid)
            emitted.append((s.rid, tok))
        return emitted

    def _retire(self) -> list[int]:
        done = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            if s.generated[-1] == self.eos_id or len(s.generated) >= s.max_new:
                finish_s = self.clock_s
                self.finished.append({
                    "rid": s.rid, "tokens": list(s.generated),
                    "arrival_s": s.arrival_s, "finish_s": finish_s,
                    "latency_s": finish_s - s.arrival_s})
                self.kv.release(s.rid)
                self._extras.pop(s.rid, None)
                self.slots[i] = None
                done.append(s.rid)
        return done

    # -- the scheduler step ------------------------------------------------
    def step(self) -> StepResult:
        """Advance the engine by one scheduler step.

        Admission (prefill) and decode of already-active slots share the
        step; the step's simulated latency is charged *before* outputs
        are processed, from the working set the step actually touches.
        With nothing active and nothing due, the clock fast-forwards to
        the next arrival (an idle step)."""
        if not self.queue and not self._active_slot_ids():
            raise RuntimeError("engine is drained: nothing queued or active")
        active_before = self._active_slot_ids()
        placed = self._admit()
        admitted_rids = [r.rid for _, r in placed]
        decode_rids = [self.slots[i].rid for i in active_before]

        if placed and decode_rids:
            kind = "mixed"
            lat = self.oracle.prefill_step(self.kv, admitted_rids,
                                           decode_rids=decode_rids)
        elif placed:
            kind = "prefill"
            lat = self.oracle.prefill_step(self.kv, admitted_rids)
        elif decode_rids:
            kind = "decode"
            lat = self.oracle.decode_step(self.kv, decode_rids)
        else:
            # idle: fast-forward to the next arrival
            kind = "idle"
            nxt = min(r.arrival_s for r in self.queue)
            target = max(0, int(np.ceil(nxt * self.oracle.freq_hz)))
            cycles = max(1, target - self.clock_cycles)
            self.clock_cycles += cycles
            self._counts["idle"] += 1
            self.step_idx += 1
            res = StepResult(step=self.step_idx - 1, kind=kind,
                             cycles=cycles, sim_time_s=self.clock_s,
                             active=0, admitted=(), emitted=(),
                             finished=())
            self.step_log.append(res)
            return res

        # decode first: the vmapped kernel garbage-writes every inactive
        # row (masking is host-side), and the prefill scatter must be
        # what lands last in a just-admitted slot's cache row.
        emitted = []
        if active_before:
            emitted.extend(self._run_decode(active_before))
        if placed:
            emitted.extend(self._run_prefill(placed))

        self.clock_cycles += lat.cycles
        occupancy = len(active_before) + len(placed)
        self._occupancy_sum += occupancy
        self._occupancy_max = max(self._occupancy_max, occupancy)
        finished = self._retire()
        self._counts[kind] += 1
        self.step_idx += 1
        res = StepResult(step=self.step_idx - 1, kind=kind,
                         cycles=lat.cycles, sim_time_s=self.clock_s,
                         active=occupancy, admitted=tuple(admitted_rids),
                         emitted=tuple(emitted), finished=tuple(finished),
                         llc_hit_rate=lat.metrics.hit_rate)
        self.step_log.append(res)
        return res

    def run(self, *, max_steps: int | None = None) -> EngineStats:
        """Run until the queue and every slot drain (or max_steps)."""
        n = 0
        while self.queue or self._active_slot_ids():
            if max_steps is not None and n >= max_steps:
                break
            self.step()
            n += 1
        return self.stats()

    def stats(self) -> EngineStats:
        lat = sorted(f["latency_s"] for f in self.finished)
        tokens = sum(len(f["tokens"]) for f in self.finished)
        busy = sum(v for k, v in self._counts.items() if k != "idle")
        t = self.clock_s
        return EngineStats(
            requests=len(self.finished), tokens=tokens,
            steps=self.step_idx,
            prefill_steps=self._counts["prefill"] + self._counts["mixed"],
            decode_steps=self._counts["decode"] + self._counts["mixed"],
            idle_steps=self._counts["idle"],
            sim_time_s=t,
            tokens_per_s=tokens / t if t > 0 else 0.0,
            latency_p50_s=_nearest_rank(lat, 50),
            latency_p99_s=_nearest_rank(lat, 99),
            mean_occupancy=self._occupancy_sum / max(1, busy),
            max_occupancy=self._occupancy_max)

    # -- checkpoint / restore ---------------------------------------------
    def _fingerprint(self) -> tuple:
        return (self.cache_len, self.block_size, self.max_slots,
                self.eos_id, self.temperature, self.seed)

    def checkpoint(self) -> dict:
        """Host-side snapshot of every piece of scheduler state (caches
        as numpy).  Restoring into a fresh engine with the same config +
        params resumes bit-identically (tests/test_serve.py)."""
        caches = (None if self._caches is None else
                  jax.tree_util.tree_map(np.asarray, self._caches))
        return {
            "fingerprint": self._fingerprint(),
            "clock_cycles": self.clock_cycles,
            "step_idx": self.step_idx,
            "counts": dict(self._counts),
            "occupancy_sum": self._occupancy_sum,
            "occupancy_max": self._occupancy_max,
            "queue": [r.to_record() for r in self.queue],
            "extras": {rid: {k: v.copy() for k, v in ex.items()}
                       for rid, ex in self._extras.items()},
            "slots": [None if s is None else dataclasses.asdict(s)
                      for s in self.slots],
            "kv": self.kv.snapshot(),
            "caches": caches,
            "finished": [dict(f) for f in self.finished],
        }

    def restore(self, snap: dict) -> None:
        if tuple(snap["fingerprint"]) != self._fingerprint():
            raise ValueError(
                f"checkpoint fingerprint {snap['fingerprint']} does not "
                f"match engine config {self._fingerprint()}")
        self.clock_cycles = int(snap["clock_cycles"])
        self.step_idx = int(snap["step_idx"])
        self._counts = dict(snap["counts"])
        self._occupancy_sum = int(snap["occupancy_sum"])
        self._occupancy_max = int(snap["occupancy_max"])
        self.queue = collections.deque(
            Request.from_record(r) for r in snap["queue"])
        self._extras = {int(rid): {k: np.asarray(v) for k, v in ex.items()}
                        for rid, ex in snap["extras"].items()}
        self.slots = [None if s is None else _Slot(**s)
                      for s in snap["slots"]]
        self.kv.restore(snap["kv"])
        if snap["caches"] is None:
            self._caches = None
            self._axes = None
        else:
            self._caches = jax.tree_util.tree_map(jnp.asarray,
                                                  snap["caches"])
            self._axes = cache_slot_axes(self._caches)
        self.finished = [dict(f) for f in snap["finished"]]
        self.step_log = []

    # -- deprecated seed API ----------------------------------------------
    def generate(self, batch: dict, max_new: int, *, seed: int = 0
                 ) -> GenerationResult:
        """Seed-era padded static-batch generation.

        .. deprecated:: round-trips through the continuous-batching
           queue; greedy tokens are bit-identical to the seed loop
           (per-row argmax decode is batch-size invariant).  Use
           ``submit()`` + ``run()`` and the typed records instead.
        """
        warnings.warn(
            "ServeEngine.generate(batch, max_new) is deprecated; submit "
            "typed Requests and run() the continuous-batching scheduler",
            DeprecationWarning, stacklevel=2)
        if self.queue or self._active_slot_ids():
            raise RuntimeError("generate() shim requires a drained engine")
        toks = np.asarray(batch["tokens"])
        b = toks.shape[0]
        extras = {k: np.asarray(v) for k, v in batch.items()
                  if k != "tokens"}
        base = 1 + max((f["rid"] for f in self.finished), default=-1)
        rids = list(range(base, base + b))
        for i, rid in enumerate(rids):
            self.submit(Request(rid=rid, tokens=tuple(int(t)
                                                      for t in toks[i]),
                                max_new=max_new, arrival_s=self.clock_s),
                        extras={k: v[i] for k, v in extras.items()} or None)
        self.run()
        by_rid = {f["rid"]: f["tokens"] for f in self.finished}
        rows = [by_rid[rid] for rid in rids]
        n_cols = max(len(r) for r in rows)
        out = np.full((b, n_cols), self.eos_id, np.int32)
        for i, r in enumerate(rows):
            out[i, :len(r)] = r
        lengths = np.argmax(out == self.eos_id, axis=1)
        lengths = np.where((out == self.eos_id).any(axis=1), lengths,
                           n_cols)
        return GenerationResult(tokens=out, lengths=lengths, steps=n_cols)
