"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 1 attn : 2 rec.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        block_pattern=("rec", "rec", "attn"),
        local_window=2048,
        rglru_width=4096,
        act="gelu",
        gated_mlp=True,
        rope_fraction=0.5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        num_layers=4,  # one full [rec, rec, attn] group + 1 remainder rec
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block_pattern=("rec", "rec", "attn"),
        local_window=16,
        rglru_width=64,
        act="gelu",
        gated_mlp=True,
        rope_fraction=0.5,
    )
