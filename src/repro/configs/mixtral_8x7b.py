"""mixtral-8x7b — sparse MoE transformer, 8 experts top-2, sliding-window attn.

[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
SWA window 4096 makes long_500k decode feasible via a rolling KV buffer.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32_000,
        num_experts=8,
        num_experts_per_tok=2,
        sliding_window=4096,
        act="silu",
        gated_mlp=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=4,
        num_experts_per_tok=2,
        sliding_window=16,
        act="silu",
        gated_mlp=True,
    )
