"""chatglm3-6b — dense GQA transformer with 2d (half-dim) RoPE.

[arXiv:2406.12793; hf] 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
ChatGLM applies rotary embedding to half of each head's dims.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65_024,
        rope_fraction=0.5,
        attn_bias=True,  # chatglm uses qkv bias ("add_qkv_bias")
        act="silu",
        gated_mlp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        rope_fraction=0.5,
        attn_bias=True,
        act="silu",
        gated_mlp=True,
    )
