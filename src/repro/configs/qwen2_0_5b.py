"""qwen2-0.5b — dense GQA transformer with QKV bias and tied embeddings.

[arXiv:2407.10671; hf] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        family="dense",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151_936,
        attn_bias=True,
        tie_embeddings=True,
        act="silu",
        gated_mlp=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,  # keeps the 7:1 q:kv flavour via kv=2 group=2
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_bias=True,
        tie_embeddings=True,
        act="silu",
        gated_mlp=True,
    )
