"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

All ten assigned architectures plus the paper's own workload config
(``nvdla-yolov3``, consumed by ``repro.core``).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    shape_applicable,
    pad_to,
)

_ARCH_MODULES = {
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "internvl2-26b": "repro.configs.internvl2_26b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).config()


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).smoke_config()
