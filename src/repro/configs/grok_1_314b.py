"""grok-1-314b — large sparse MoE transformer, 8 experts top-2.

[hf:xai-org/grok-1; unverified] 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072.  Grok-1 uses attention-logit tanh soft-capping (30.0).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131_072,
        num_experts=8,
        num_experts_per_tok=2,
        attn_logit_softcap=30.0,
        act="gelu",
        gated_mlp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=4,
        num_experts_per_tok=2,
        attn_logit_softcap=30.0,
        act="gelu",
        gated_mlp=True,
    )
