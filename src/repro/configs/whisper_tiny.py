"""whisper-tiny — encoder-decoder audio transformer (backbone only).

[arXiv:2212.04356; unverified] 4L d_model=384 6H d_ff=1536 vocab=51865.
The conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings of shape (batch, encoder_len, d_model).
Whisper uses pre-LN LayerNorm, GELU MLPs (not gated) and learned positions
(no RoPE) — rope_fraction=0 turns rotary off.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        num_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51_865,
        is_encoder_decoder=True,
        num_encoder_layers=4,
        encoder_len=1500,
        rope_fraction=0.0,
        act="gelu",
        gated_mlp=False,
        use_layer_norm=True,
        attn_bias=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke",
        family="encdec",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        is_encoder_decoder=True,
        num_encoder_layers=2,
        encoder_len=32,
        rope_fraction=0.0,
        act="gelu",
        gated_mlp=False,
        use_layer_norm=True,
        attn_bias=True,
    )
