"""mamba2-130m — attention-free SSM with state-space duality (SSD).

[arXiv:2405.21060; unverified] 24L d_model=768 d_ff=0 vocab=50280,
ssm_state=128.  Mamba-2 blocks replace both attention and MLP; the block is
`in_proj -> conv1d -> SSD -> gated out_proj` with expand=2, head_dim=64.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        ssm_state=128,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
        ssm_ngroups=1,
        block_pattern=("ssm",),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=256,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=32,
        ssm_chunk=32,
        ssm_ngroups=1,
        block_pattern=("ssm",),
        tie_embeddings=True,
    )
