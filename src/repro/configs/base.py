"""Model / run configuration dataclasses.

Every assigned architecture instantiates :class:`ModelConfig`; shape points
(seq_len x global_batch x mode) are :class:`ShapeConfig`.  Configs are plain
frozen dataclasses so they hash, print, and diff cleanly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # -- attention ---------------------------------------------------------
    attn_bias: bool = False            # qwen2-style QKV bias
    attn_logit_softcap: float = 0.0    # grok-style tanh soft-capping (0 = off)
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0         # chatglm applies rotary to half the dims
    sliding_window: int = 0            # mixtral SWA window (0 = full attention)

    # -- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # -- SSM (mamba-2 / SSD) ------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # -- hybrid (recurrentgemma / griffin) -----------------------------------
    block_pattern: tuple[str, ...] = ("attn",)   # cycled over layers
    local_window: int = 0                        # griffin local-attn window
    rglru_width: int = 0                         # RG-LRU recurrent width
    rglru_conv: int = 4

    # -- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_len: int = 1500            # whisper 30 s of audio -> 1500 frames

    # -- VLM (internvl stub) --------------------------------------------------
    num_patches: int = 0               # precomputed patch embeddings prefix

    # -- misc ----------------------------------------------------------------
    norm_eps: float = 1e-5
    act: str = "silu"                  # silu | gelu | geglu-style gating below
    gated_mlp: bool = True             # SwiGLU/GeGLU two-matrix gate
    tie_embeddings: bool = False
    use_layer_norm: bool = False       # whisper uses LayerNorm, others RMSNorm
    dtype: str = "bfloat16"
    remat: str = "layer"               # layer | none
    #: Unroll lax.scan loops (layer stack, attention q-chunks, SSD chunks).
    #: The dry-run sets this so compiled cost_analysis counts every
    #: iteration (XLA costs a while-loop body exactly once); runtime keeps
    #: scans rolled for small HLO and fast compiles.
    unroll_scans: bool = False
    #: Query-chunk length for memory-efficient attention (0 = module
    #: default).  Perf knob: under sequence parallelism a single chunk
    #: (= seq_len) avoids resharding collectives from chunked slicing.
    attn_q_chunk: int = 0
    #: KV-cache storage dtype: "bfloat16" or "int8" (per-token, per-head
    #: symmetric scales — the paper's int8-inference-engine insight applied
    #: to the serving cache: halves cache bytes and decode HBM traffic).
    kv_cache_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if memory/compute per decoded token is o(seq_len)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer block kind, cycling block_pattern (decoder stack)."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    # -- analytic parameter / FLOP accounting (used by roofline + docs) ----
    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n_q, n_kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        mlp = (3 if self.gated_mlp else 2) * d * ff
        if self.num_experts:
            mlp *= self.num_experts
            mlp += d * self.num_experts  # router
        per_kind = {}
        per_kind["attn"] = attn + mlp + 2 * d
        per_kind["local_attn"] = per_kind["attn"]
        if self.family == "ssm":
            di, st = self.ssm_d_inner, self.ssm_state
            ssm = d * (2 * di + 2 * self.ssm_ngroups * st + self.ssm_nheads)
            ssm += self.ssm_conv * (di + 2 * self.ssm_ngroups * st)
            ssm += di * d + 3 * self.ssm_nheads  # out proj + A/dt/D params
            per_kind["ssm"] = ssm + 2 * d
        if "rec" in self.block_pattern:
            w = self.rglru_width or d
            rec = d * w * 2 + self.rglru_conv * w + 2 * w * 2 + w * d
            rec += mlp + 2 * d
            per_kind["rec"] = rec
        total = sum(per_kind.get(k, per_kind.get("attn", 0))
                    for k in self.layer_kinds())
        if self.is_encoder_decoder:
            # encoder self-attn + mlp; decoder adds cross-attn
            total += self.num_encoder_layers * (attn + mlp + 2 * d)
            total += self.num_layers * (attn + 2 * d)  # cross attention
        total += v * d  # embeddings
        if not self.tie_embeddings:
            total += v * d
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top-k of experts)."""
        if not self.num_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_mlp = (3 if self.gated_mlp else 2) * d * ff
        inactive = (self.num_experts - self.num_experts_per_tok) * dense_mlp
        return int(self.param_count() - self.num_layers * inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether the (arch, shape) cell runs; reason recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full O(L^2) attention; 512k decode requires sub-quadratic memory"
    return True, ""


def pad_to(x: int, multiple: int) -> int:
    return int(math.ceil(x / multiple) * multiple)
