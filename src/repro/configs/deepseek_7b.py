"""deepseek-7b — llama-architecture dense transformer (full MHA, kv=32).

[arXiv:2401.02954; hf] 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        family="dense",
        num_layers=30,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=11008,
        vocab_size=102_400,
        act="silu",
        gated_mlp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        act="silu",
        gated_mlp=True,
    )
