"""internvl2-26b — VLM: InternViT frontend (stub) + InternLM2-20B backbone.

[arXiv:2404.16821; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
Per the assignment the vision frontend is a STUB: ``input_specs`` supplies
precomputed patch embeddings (batch, num_patches, d_model) that are prepended
to the token embeddings.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92_553,
        num_patches=256,
        act="silu",
        gated_mlp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_patches=8,
        act="silu",
        gated_mlp=True,
    )
