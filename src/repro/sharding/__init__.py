from repro.sharding.specs import (  # noqa: F401
    AxisRules,
    DEFAULT_PARAM_RULES,
    DEFAULT_ACT_RULES,
    activate_rules,
    active_rules,
    logical_constraint,
    spec_for,
    sharding_for,
    param_shardings,
    abstract_param_shardings,
)
