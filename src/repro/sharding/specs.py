"""Logical-axis -> mesh-axis resolution with divisibility fallback.

Model code annotates parameters (``repro.types.Param``) and activations
(:func:`logical_constraint`) with *logical* axis names.  A launcher activates
an :class:`AxisRules` (mesh + mapping) and every annotation resolves to a
``PartitionSpec``:

* each logical axis maps to an ordered tuple of candidate mesh axes;
* a candidate is used only if (a) it exists in the mesh, (b) it has not been
  consumed by an earlier dimension of the same array, and (c) the dimension
  size is divisible by the product of chosen axis sizes — otherwise it is
  dropped (this is how e.g. qwen2's 14 heads gracefully decline 16-way TP
  while its MLP still tensor-parallelizes);
* dropped axes are recorded so the dry-run can report them.

This mirrors t5x/MaxText logical axis rules but adds the divisibility
fallback needed to drive ten heterogeneous architectures through one fixed
production mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.types import Param, is_param

# Parameter logical axes -------------------------------------------------
# "embed" is the FSDP axis: weight d_model dims shard over the data(+pod)
# axes, ZeRO-3 style; XLA inserts the per-layer all-gather at use.
DEFAULT_PARAM_RULES: dict[str, tuple[str, ...]] = {
    "embed": ("data", "pod"),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "vocab": ("model",),
    "experts": (),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "ssm_heads": ("model",),
    "rglru": ("model",),
    "rglru_in": ("data", "pod"),
    "conv": (),
    "norm": (),
}

# Activation logical axes -------------------------------------------------
DEFAULT_ACT_RULES: dict[str, tuple[str, ...]] = {
    "act_batch": ("pod", "data"),
    "act_seq": (),
    "act_embed": (),
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_experts": (),
    "cache_seq": (),
    "act_ssm_inner": ("model",),
    "act_rglru": ("model",),
}


@dataclasses.dataclass
class AxisRules:
    mesh: Mesh
    rules: dict[str, tuple[str, ...]]
    #: logical axes that failed divisibility at least once (reporting only)
    dropped: set = dataclasses.field(default_factory=set)

    def mesh_axis_size(self, name: str) -> int:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[name]


_state = threading.local()


def active_rules() -> AxisRules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activate_rules(mesh: Mesh, overrides: Mapping[str, tuple[str, ...]] | None = None):
    rules = dict(DEFAULT_PARAM_RULES)
    rules.update(DEFAULT_ACT_RULES)
    if overrides:
        rules.update(overrides)
    prev = getattr(_state, "rules", None)
    _state.rules = AxisRules(mesh=mesh, rules=rules)
    try:
        with mesh:
            yield _state.rules
    finally:
        _state.rules = prev


def spec_for(shape: Sequence[int], axes: Sequence[str | None],
             rules: AxisRules | None = None) -> P:
    """Resolve logical axes to a PartitionSpec under the active rules."""
    r = rules or active_rules()
    if r is None:
        raise RuntimeError("no active AxisRules; wrap in activate_rules(mesh)")
    used: set[str] = set()
    out: list = []
    for dim, ax in zip(shape, axes):
        if ax is None or ax not in r.rules:
            out.append(None)
            continue
        chosen: list[str] = []
        factor = 1
        for mesh_ax in r.rules[ax]:
            if mesh_ax not in r.mesh.axis_names or mesh_ax in used:
                continue
            size = r.mesh_axis_size(mesh_ax)
            if dim % (factor * size) != 0:
                r.dropped.add((ax, mesh_ax, dim))
                continue
            chosen.append(mesh_ax)
            factor *= size
        used.update(chosen)
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(tuple(chosen))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_for(shape, axes, rules: AxisRules | None = None) -> NamedSharding:
    r = rules or active_rules()
    return NamedSharding(r.mesh, spec_for(shape, axes, r))


def logical_constraint(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op when no rules active."""
    r = active_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding_for(x.shape, axes, r))


def param_shardings(param_tree, rules: AxisRules | None = None):
    """Param tree -> matching NamedSharding tree (for jit in_shardings)."""
    r = rules or active_rules()

    def _one(p: Param):
        return sharding_for(p.value.shape, p.axes, r)

    return jax.tree.map(_one, param_tree, is_leaf=is_param)


def abstract_param_shardings(values_tree, axes_tree, rules: AxisRules | None = None):
    """Same as param_shardings but from split (values, AxesSpec) trees.

    ``values_tree`` may contain ShapeDtypeStruct leaves (dry-run path).
    """
    r = rules or active_rules()
    return jax.tree.map(
        lambda v, a: sharding_for(v.shape, a.axes, r), values_tree, axes_tree
    )
