"""Sharded checkpointing: manifest + per-leaf arrays + integrity hashes.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, crc32 per leaf
        leaf_00000.npy ... one file per pytree leaf
        COMMIT             written last — a checkpoint without COMMIT is
                           torn (crashed mid-save) and is ignored/cleaned

Fault-tolerance properties exercised by the tests:
* atomic commit — a kill mid-save never corrupts the latest checkpoint;
* restore() validates crc32 of every leaf before handing data back;
* elastic restore — arrays are saved as *global* logical arrays, so a
  restart may resume onto a different mesh/sharding (reshard-on-restore:
  pass ``shardings`` to place leaves directly onto the new mesh);
* async save — ``CheckpointManager(async_save=True)`` snapshots to host
  memory synchronously and writes in a background thread, so the train
  loop only blocks for the device->host copy.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
import zlib

import jax
import numpy as np


class CheckpointCorruptError(OSError):
    """A checkpoint on disk cannot be trusted: torn commit, unreadable
    or tampered manifest, missing leaf file, or a checksum mismatch.
    Subclasses ``OSError`` so callers guarding restores with
    ``except OSError`` keep working.  The message names the artifact
    and the step so an operator can delete exactly the bad directory."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _fsync_file(f) -> None:
    f.flush()
    os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    """Flush directory metadata (renames, creates) to stable storage;
    silently skipped where directories cannot be opened read-only."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(tree, directory: str, step: int) -> str:
    """Synchronous atomic save: every file is written and fsync'd in a
    temp directory, the COMMIT marker lands last, and the final rename
    (plus parent-directory fsync) publishes the whole checkpoint — a
    crash at any instant leaves either the old checkpoint or a torn
    temp directory that ``latest_step``/``restore`` ignore.  Returns
    the checkpoint path."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = jax.tree.flatten(tree)
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves), "step": step,
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        with open(os.path.join(tmp, f"leaf_{i:05d}.npy"), "wb") as f:
            np.save(f, arr)
            _fsync_file(f)
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": _crc(arr)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        _fsync_file(f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
        _fsync_file(f)
    _fsync_dir(tmp)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _fsync_dir(directory)
    return path


def latest_step(directory: str) -> int | None:
    """Largest committed step in `directory` (ignores torn checkpoints)."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMIT")):
            s = int(m.group(1))
            best = s if best is None or s > best else best
    return best


def restore(tree_like, directory: str, step: int | None = None, *,
            shardings=None):
    """Restore into the structure of `tree_like` (values are ignored).

    ``shardings``: optional pytree of NamedSharding matching `tree_like` —
    leaves are placed directly onto the target mesh (elastic reshard).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise CheckpointCorruptError(
            f"checkpoint {path} has no COMMIT marker — it is torn "
            "(crashed mid-save); delete the directory or restore an "
            "earlier step")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path} manifest is unreadable ({e}); the "
            "checkpoint cannot be validated — delete it or restore an "
            "earlier step") from e

    leaves_like, treedef = jax.tree.flatten(tree_like)
    if manifest.get("n_leaves") != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest.get('n_leaves')} leaves, "
            f"target tree has {len(leaves_like)}")
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves_like))

    out = []
    for entry, shard in zip(manifest["leaves"], shard_leaves):
        leaf_path = os.path.join(path, f"leaf_{entry['index']:05d}.npy")
        try:
            arr = np.load(leaf_path)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(
                f"leaf file {leaf_path} is missing or undeserializable "
                f"({e}) despite a committed manifest — the checkpoint "
                "is corrupt; delete it or restore an earlier step") from e
        if _crc(arr) != entry["crc32"]:
            raise CheckpointCorruptError(
                f"crc mismatch for leaf {entry['index']} in {path}: "
                f"stored {entry['crc32']}, recomputed {_crc(arr)} — the "
                "leaf bytes changed after commit; delete the checkpoint "
                "or restore an earlier step")
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out)


@dataclasses.dataclass
class CheckpointManager:
    """Keeps the last `keep` checkpoints; optional async background writes."""

    directory: str
    keep: int = 3
    async_save: bool = False
    _thread: threading.Thread | None = None

    def save(self, tree, step: int) -> None:
        # snapshot to host synchronously (device buffers may be donated)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(host_tree, step), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(host_tree, step)

    def _save_and_gc(self, tree, step: int) -> None:
        save(tree, self.directory, step)
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.directory))
            if m)
        for old in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{old:09d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like, *, shardings=None):
        self.wait()
        return restore(tree_like, self.directory, None, shardings=shardings)
