"""Sharded checkpointing: manifest + per-leaf arrays + integrity hashes.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, crc32 per leaf
        leaf_00000.npy ... one file per pytree leaf
        COMMIT             written last — a checkpoint without COMMIT is
                           torn (crashed mid-save) and is ignored/cleaned

Fault-tolerance properties exercised by the tests:
* atomic commit — a kill mid-save never corrupts the latest checkpoint;
* restore() validates crc32 of every leaf before handing data back;
* elastic restore — arrays are saved as *global* logical arrays, so a
  restart may resume onto a different mesh/sharding (reshard-on-restore:
  pass ``shardings`` to place leaves directly onto the new mesh);
* async save — ``CheckpointManager(async_save=True)`` snapshots to host
  memory synchronously and writes in a background thread, so the train
  loop only blocks for the device->host copy.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
import zlib

import jax
import numpy as np


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save(tree, directory: str, step: int) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = jax.tree.flatten(tree)
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves), "step": step,
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": _crc(arr)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_step(directory: str) -> int | None:
    """Largest committed step in `directory` (ignores torn checkpoints)."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMIT")):
            s = int(m.group(1))
            best = s if best is None or s > best else best
    return best


def restore(tree_like, directory: str, step: int | None = None, *,
            shardings=None):
    """Restore into the structure of `tree_like` (values are ignored).

    ``shardings``: optional pytree of NamedSharding matching `tree_like` —
    leaves are placed directly onto the target mesh (elastic reshard).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_like, treedef = jax.tree.flatten(tree_like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"target tree has {len(leaves_like)}")
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves_like))

    out = []
    for entry, shard in zip(manifest["leaves"], shard_leaves):
        arr = np.load(os.path.join(path, f"leaf_{entry['index']:05d}.npy"))
        if _crc(arr) != entry["crc32"]:
            raise IOError(f"crc mismatch for leaf {entry['index']} in {path}")
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out)


@dataclasses.dataclass
class CheckpointManager:
    """Keeps the last `keep` checkpoints; optional async background writes."""

    directory: str
    keep: int = 3
    async_save: bool = False
    _thread: threading.Thread | None = None

    def save(self, tree, step: int) -> None:
        # snapshot to host synchronously (device buffers may be donated)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(host_tree, step), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(host_tree, step)

    def _save_and_gc(self, tree, step: int) -> None:
        save(tree, self.directory, step)
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.directory))
            if m)
        for old in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{old:09d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like, *, shardings=None):
        self.wait()
        return restore(tree_like, self.directory, None, shardings=shardings)
