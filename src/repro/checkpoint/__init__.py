from repro.checkpoint.store import (  # noqa: F401
    CheckpointCorruptError,
    CheckpointManager,
    latest_step,
    restore,
    save,
)
