"""Mamba-2 SSD intra-chunk kernel (state-space duality, arXiv:2405.21060).

The SSD decomposition splits the linear-recurrence into (a) dense
intra-chunk matmuls and (b) a cheap inter-chunk state recurrence.  (a) is
>95% of the FLOPs and is MXU-shaped — this kernel computes, per
(batch, chunk) grid cell and per head:

    scores(l,s) = (C_l . B_s) * exp(cum_l - cum_s) * dt_s   (causal l >= s)
    y_intra     = scores @ x                                 (q x q @ q x p)
    state       = B^T @ (exp(cum_last - cum) * dt * x)       (n x q @ q x p)

The (q, q) score matrix lives only in VMEM/registers — chunk length q
(default 256) bounds it to 256 KiB fp32, the same working-set discipline
as the flash-attention kernel.  The inter-chunk scan (b) stays in JAX
(``repro.models.ssm``): it is O(nc * h * n * p) elementwise work.

VMEM @ q=256, h-loop over 24 heads, p=64, n=128:
x tile 256*24*64*4 = 1.5 MiB + B/C 256*128*4 = 128 KiB each + per-head
(q,q)+(q,p)+(n,p) intermediates < 0.4 MiB -> ~2 MiB, MXU dims 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, st_ref, *,
                q: int, h: int, p: int, n: int):
    x = x_ref[0, 0]            # (q, h, p) fp32
    dt = dt_ref[0, 0]          # (q, h)
    cum = cum_ref[0, 0]        # (q, h)  running sum of dt*A (negative)
    bmat = b_ref[0, 0]         # (q, n)
    cmat = c_ref[0, 0]         # (q, n)

    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, q)
    causal = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))

    for head in range(h):      # static unroll: one (q,q)@(q,p) MXU op each
        seg = cum[:, head][:, None] - cum[:, head][None, :]       # (q, q)
        seg = jnp.where(causal, seg, NEG)
        scores = cb * jnp.exp(seg) * dt[:, head][None, :]
        xh = x[:, head, :]                                        # (q, p)
        y_ref[0, 0, :, head, :] = jax.lax.dot_general(
            scores, xh, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        w = jnp.exp(cum[-1, head] - cum[:, head]) * dt[:, head]   # (q,)
        st_ref[0, 0, head] = jax.lax.dot_general(
            bmat, xh * w[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                   # (n, p)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_kernel(x: jax.Array, dt: jax.Array, cum: jax.Array,
                           B: jax.Array, C: jax.Array, *,
                           interpret: bool = False):
    """x (bb, nc, q, h, p); dt/cum (bb, nc, q, h); B/C (bb, nc, q, n).

    Returns (y_intra (bb, nc, q, h, p), states (bb, nc, h, n, p)), fp32.
    Single SSM group (g == 1), the mamba2-130m configuration.
    """
    bb, nc, q, h, p = x.shape
    n = B.shape[-1]
    grid = (bb, nc)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, q=q, h=h, p=p, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, h, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, q, h), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, h), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, h, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, h, n, p), lambda i, j: (i, j, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bb, nc, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, cum, B, C)
