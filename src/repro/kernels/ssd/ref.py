"""Pure-jnp oracle for the SSD intra-chunk computation (g == 1)."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_intra_chunk_ref(x, dt, cum, B, C):
    """Same contract as the kernel: returns (y_intra, states)."""
    bb, nc, q, h, p = x.shape
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (bb,nc,l,s,h)
    causal = jnp.tril(jnp.ones((q, q), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bcln,bcsn->bcls", C, B)                  # (bb,nc,l,s)
    scores = cb[:, :, :, :, None] * decay * dt[:, :, None, :, :]
    y = jnp.einsum("bclsh,bcshp->bclhp", scores, x)
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dt                 # (bb,nc,q,h)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchnp", B, w, x)
    return y, states
