from repro.kernels.ssd.ops import ssd_intra_chunk  # noqa: F401
