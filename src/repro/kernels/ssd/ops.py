"""Public SSD op: chunking plumbing around the intra-chunk kernel.

``ssd_intra_chunk`` mirrors the dataflow of ``repro.models.ssm.ssd_chunked``
— the kernel owns the heavy intra-chunk matmuls; the caller composes the
inter-chunk state scan and D-skip exactly as the pure-jnp path does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import kernel as K


def ssd_intra_chunk(x: jax.Array, dt: jax.Array, A: jax.Array,
                    B: jax.Array, C: jax.Array, *, chunk: int,
                    interpret: bool = False):
    """x (Bb, L, H, P); dt (Bb, L, H) post-softplus; A (H,) negative;
    B/C (Bb, L, N) single-group. Returns (y_intra, states, cum) with
    cum the within-chunk decay prefix the inter-chunk scan needs."""
    bb, l, h, p = x.shape
    n = B.shape[-1]
    q = chunk if l % chunk == 0 and l > chunk else l
    nc = l // q
    xc = x.reshape(bb, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bb, nc, q, h).astype(jnp.float32)
    cum = jnp.cumsum(dtc * A[None, None, None, :], axis=2)
    bc = B.reshape(bb, nc, q, n).astype(jnp.float32)
    cc = C.reshape(bb, nc, q, n).astype(jnp.float32)
    y, states = K.ssd_intra_chunk_kernel(xc, dtc, cum, bc, cc,
                                         interpret=interpret)
    return y, states, cum
