"""Pure-jnp oracle for the fused post-processing pass."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(x, kind: str):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "tanh":
        return jnp.tanh(x)
    return x


def postprocess_ref(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                    act: str = "relu", pool: int = 1,
                    out_dtype=jnp.bfloat16) -> jax.Array:
    y = x.astype(jnp.float32) * scale[None, None, None, :] \
        + bias[None, None, None, :]
    y = _act(y, act)
    if pool > 1:
        n, h, w, c = y.shape
        y = y.reshape(n, h // pool, pool, w // pool, pool, c).max(axis=(2, 4))
    return y.astype(out_dtype)
