"""Public postproc op: pad/crop plumbing around the fused kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.postproc import kernel as K


def postprocess(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                act: str = "relu", pool: int = 1, out_dtype=jnp.bfloat16,
                interpret: bool = False) -> jax.Array:
    """Fused bias+scale+activation (+maxpool). x (N, H, W, C)."""
    n, h, w, c = x.shape
    bh = min(K.DEFAULT_BH, h)
    bw = min(K.DEFAULT_BW, w)
    bh = max(pool, (bh // pool) * pool)
    bw = max(pool, (bw // pool) * pool)
    ph = (-h) % bh
    pw = (-w) % bw
    if ph or pw:
        # pad with -inf-like value so maxpool ignores the padding
        pad_val = jnp.asarray(-3e38, x.dtype) if pool > 1 else jnp.asarray(0, x.dtype)
        x = jnp.pad(x, ((0, 0), (0, ph), (0, pw), (0, 0)),
                    constant_values=pad_val)
    out = K.postprocess_kernel(x, scale, bias, act=act, pool=pool, bh=bh,
                               bw=bw, out_dtype=out_dtype, interpret=interpret)
    return out[:, : h // pool, : w // pool, :]
