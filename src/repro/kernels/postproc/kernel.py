"""NVDLA post-processing unit (SDP + PDP) as one fused Pallas pass.

NVDLA streams conv-core output through SDP (bias / per-channel scale /
activation) and PDP (pooling) before it ever returns to DRAM.  The TPU
analogue fuses the same chain into one VMEM-resident pass over NHWC
tiles: each (1, bh, bw, C) activation tile is loaded once, gets
bias+scale+activation on the VPU, is max-pooled in-register, and only the
pooled (1, bh/p, bw/p, C) tile is written back — a (1 + 1/p^2)x traffic
cost instead of the 2x + 2/p^2 of separate passes.

Channel stays the innermost (lane) dimension; bh/bw tile the sublane
grid.  Pool windows never straddle tiles because bh % pool == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BH = 32
DEFAULT_BW = 32


def _act(x, kind: str):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "tanh":
        return jnp.tanh(x)
    return x  # "none"


def _postproc_kernel(x_ref, scale_ref, bias_ref, o_ref, *, act: str,
                     pool: int):
    x = x_ref[...].astype(jnp.float32)            # (1, bh, bw, C)
    x = x * scale_ref[...] + bias_ref[...]
    x = _act(x, act)
    if pool > 1:
        _, bh, bw, c = x.shape
        x = x.reshape(1, bh // pool, pool, bw // pool, pool, c)
        x = jnp.max(x, axis=(2, 4))
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "pool", "bh", "bw",
                                             "out_dtype", "interpret"))
def postprocess_kernel(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                       act: str = "relu", pool: int = 1,
                       bh: int = DEFAULT_BH, bw: int = DEFAULT_BW,
                       out_dtype=jnp.bfloat16,
                       interpret: bool = False) -> jax.Array:
    """x (N, H, W, C); scale/bias (C,).  H % bh == W % bw == 0,
    bh % pool == bw % pool == 0 (ops.py pads)."""
    n, h, w, c = x.shape
    grid = (n, h // bh, w // bw)
    return pl.pallas_call(
        functools.partial(_postproc_kernel, act=act, pool=pool),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bh, bw, c), lambda b, i, j: (b, i, j, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda b, i, j: (0, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda b, i, j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bh // pool, bw // pool, c),
                               lambda b, i, j: (b, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h // pool, w // pool, c),
                                       out_dtype),
        interpret=interpret,
    )(x, scale.reshape(1, 1, 1, c), bias.reshape(1, 1, 1, c))
