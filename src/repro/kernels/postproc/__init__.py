from repro.kernels.postproc.ops import postprocess  # noqa: F401
