"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel package has:
* ``kernel.py`` — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling,
* ``ops.py``    — jit'd public wrapper (padding, shape plumbing),
* ``ref.py``    — pure-jnp oracle the tests sweep against.

Kernels validate in ``interpret=True`` mode on CPU; BlockSpecs are written
for the real TPU memory hierarchy (HBM -> VMEM -> MXU/VPU).
"""
