"""Public SWA attention op: (B, S, H, D) layout + GQA + padding plumbing."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.swa import kernel as K


def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int, scale: float | None = None,
                  softcap: float = 0.0, block: int = K.DEFAULT_BQ,
                  interpret: bool = False) -> jax.Array:
    """Causal banded attention. q (B, S, Hq, D); k/v (B, S, Hkv, D).

    GQA expands kv head-wise; window >= S degrades to full flash attention.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    if hq != hkv:
        group = hq // hkv
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)

    if s < block:  # small-shape fallback (tests); TPU shapes keep 256
        block = max(16, 1 << (s.bit_length() - 1))
    pad = (-s) % block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * hq, sp, d)

    out = K.swa_attention_kernel(
        to_bh(q), to_bh(k), to_bh(v), window=window, bq=block, bk=block,
        scale=scale, softcap=softcap, interpret=interpret)
    out = out.reshape(b, hq, sp, d).transpose(0, 2, 1, 3)
    return out[:, :s]
