"""Pure-jnp oracle: causal, window-banded softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      window: int, scale: float | None = None,
                      softcap: float = 0.0) -> jax.Array:
    """q/k/v (BH, S, D) -> (BH, S, D); fp32 softmax."""
    bh, s, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    scores = jnp.einsum("bld,btd->blt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(s)
    mask = (pos[:, None] >= pos[None, :]) & (pos[:, None] - pos[None, :] < window)
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("blt,btd->bld", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
