"""Sliding-window flash attention (banded) for the TPU MXU.

The band structure makes SWA prefill O(S*W): for query block i only the
kv blocks covering [i*bq - W, i*bq + bq) can contribute.  The grid is
(batch*heads, n_q_blocks, n_kv_steps) with n_kv_steps = W/bk + 1 — a
*static* band width — and the kv BlockSpec's index_map slides the window:
kv block index = clamp(i - W/bk + j).  Out-of-range steps are masked by
absolute position (the clamp makes them alias block 0, which the mask
then zeroes, so no double counting).

Online-softmax state (m, l, acc) lives in VMEM scratch across the kv
steps of one query block; the output tile is written once on the last
step — the flash policy: no (bq, S) score matrix ever exists in memory.

VMEM @ bq=bk=256, D=128: q/k/v tiles 3*256*128*2B = 192 KiB, acc
256*128*4B = 128 KiB. MXU dims (bq x D) @ (D x bk) are 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                bq: int, bk: int, nw: int, nk: int, window: int,
                scale: float, softcap: float):
    i = pl.program_id(1)   # query block
    j = pl.program_id(2)   # kv step within the band

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_block = i - nw + j                       # may be negative (clamped
    in_range = kv_block >= 0                    # in the index_map)

    @pl.when(in_range)
    def _step():
        q = q_ref[0].astype(jnp.float32)        # (bq, D)
        k = k_ref[0].astype(jnp.float32)        # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kv_block * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (qpos >= kpos) & (qpos - kpos < window) & (kpos >= 0)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                     # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                  # (bq, bk)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk", "scale",
                                             "softcap", "interpret"))
def swa_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         window: int, bq: int = DEFAULT_BQ,
                         bk: int = DEFAULT_BK, scale: float | None = None,
                         softcap: float = 0.0,
                         interpret: bool = False) -> jax.Array:
    """q/k/v (BH, S, D) -> (BH, S, D), causal, window-banded.

    S % bq == S % bk == window % bk == 0 (ops.py pads); window >= bk.
    """
    bh, s, d = q.shape
    assert bq == bk, "band indexing assumes bq == bk"
    assert s % bq == 0, "ops.py pads S to a bq multiple"
    scale = scale if scale is not None else d ** -0.5
    nq = s // bq
    nw = -(-window // bk)   # ceil: band blocks needed left of the diagonal
    nk = nw + 1             # + the diagonal block

    def kv_index(b, i, j):
        return (b, jnp.maximum(i - nw + j, 0), 0)

    return pl.pallas_call(
        functools.partial(_swa_kernel, bq=bq, bk=bk, nw=nw, nk=nk,
                          window=window, scale=scale, softcap=softcap),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
