"""NVDLA Convolutional Core, re-derived for the TPU MXU.

NVDLA's conv core is 2048 INT8 MACs fed from a 512 KiB convolutional
buffer; conv and FC layers are lowered to matrix multiplies whose operand
tiles are staged in that buffer (the "Atomic-C/K" dataflow).  The TPU
analogue keeps the *insight* — stage int8 operand tiles in fast on-chip
memory sized so DRAM/HBM traffic is streaming — and swaps the geometry:

* the MXU is a 128x128 systolic array -> block shapes are multiples of
  128 in M/N and 512 in K (int8 lanes pack 4x denser than f32);
* the "convolutional buffer" becomes the VMEM working set chosen by the
  BlockSpecs below: one (bm, bk) activation tile + one (bk, bn) weight
  tile + the (bm, bn) int32 accumulator;
* NVDLA's SDP post-processing (bias, per-channel scale, ReLU) is fused
  into the epilogue on the last K step — output leaves VMEM exactly once.

Default tiling (bm=bk=512, bn=256):  a 512x512 + w 512x256 int8 tiles
= 384 KiB + acc 512x256 int32 = 512 KiB  ->  ~0.9 MiB of VMEM, i.e. the
same "conv buffer" budget class as nv_large's 512 KiB, well under the
~128 MiB/core VMEM target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 512
DEFAULT_BN = 256
DEFAULT_BK = 512


def _matmul_kernel(a_ref, b_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
                   nk: int, relu: bool):
    """One (bm, bn) output tile; grid = (nm, nn, nk), k innermost."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 on the MXU
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out = acc * scale_ref[...] + bias_ref[...]
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "relu", "out_dtype",
                              "interpret"))
def matmul_int8_kernel(a: jax.Array, b: jax.Array, scale: jax.Array,
                       bias: jax.Array, *, bm: int = DEFAULT_BM,
                       bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                       relu: bool = False, out_dtype=jnp.bfloat16,
                       interpret: bool = False) -> jax.Array:
    """a (M, K) int8 @ b (K, N) int8 -> (M, N) out_dtype.

    scale (N,) fp32 per-output-channel dequant scale (s_a * s_w[n]);
    bias (N,) fp32.  M % bm == K % bk == N % bn == 0 (ops.py pads).
    """
    m, k = a.shape
    _, n = b.shape
    nm, nn, nk = m // bm, n // bn, k // bk
    grid = (nm, nn, nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, b, scale.reshape(1, n), bias.reshape(1, n))
