from repro.kernels.convcore.ops import conv2d_int8, matmul_int8  # noqa: F401
