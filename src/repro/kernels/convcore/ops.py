"""Public convcore ops: padding plumbing + conv-as-GEMM (im2col).

``conv2d_int8`` is the NVDLA conv-layer pipeline on the MXU: im2col the
int8 activations, run the tiled int8 GEMM kernel with the fused SDP
epilogue (bias + per-channel scale + ReLU), reshape back to NHWC.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.convcore import kernel as K


def _pad_to(x: jax.Array, axis: int, multiple: int):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def _pick_block(size: int, preferred: int, quantum: int) -> int:
    """Largest block <= preferred that is a multiple of `quantum`."""
    if size <= quantum:
        return quantum
    b = min(preferred, size)
    return max(quantum, (b // quantum) * quantum)


def matmul_int8(a: jax.Array, b: jax.Array, scale: jax.Array | None = None,
                bias: jax.Array | None = None, *, relu: bool = False,
                out_dtype=jnp.bfloat16, interpret: bool = False,
                bm: int | None = None, bn: int | None = None,
                bk: int | None = None) -> jax.Array:
    """int8 (M, K) @ (K, N) with fused dequant epilogue; any M/N/K."""
    m0, k0 = a.shape
    _, n0 = b.shape
    scale = jnp.ones((n0,), jnp.float32) if scale is None else scale
    bias = jnp.zeros((n0,), jnp.float32) if bias is None else bias

    bm = bm or _pick_block(m0, K.DEFAULT_BM, 128)
    bn = bn or _pick_block(n0, K.DEFAULT_BN, 128)
    bk = bk or _pick_block(k0, K.DEFAULT_BK, 128)

    a, _ = _pad_to(a, 0, bm)
    a, _ = _pad_to(a, 1, bk)
    b, _ = _pad_to(b, 0, bk)
    b, _ = _pad_to(b, 1, bn)
    scale, _ = _pad_to(scale, 0, bn)
    bias, _ = _pad_to(bias, 0, bn)

    out = K.matmul_int8_kernel(a, b, scale, bias, bm=bm, bn=bn, bk=bk,
                               relu=relu, out_dtype=out_dtype,
                               interpret=interpret)
    return out[:m0, :n0]


def im2col(x: jax.Array, kh: int, kw: int, *, stride: int = 1,
           padding: int = 0):
    """x (N, H, W, C) -> patches (N*H'*W', KH*KW*C), plus (H', W')."""
    n, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    # gather kh*kw shifted slices; unrolled python loop => static slices
    cols = []
    for i in range(kh):
        for j in range(kw):
            sl = x[:, i : i + (ho - 1) * stride + 1 : stride,
                   j : j + (wo - 1) * stride + 1 : stride, :]
            cols.append(sl)
    patches = jnp.stack(cols, axis=3)          # (N, H', W', KH*KW, C)
    return patches.reshape(n * ho * wo, kh * kw * c), (ho, wo)


def conv2d_int8(x: jax.Array, w: jax.Array, scale: jax.Array | None = None,
                bias: jax.Array | None = None, *, stride: int = 1,
                padding: int = 0, relu: bool = False,
                out_dtype=jnp.bfloat16, interpret: bool = False) -> jax.Array:
    """NVDLA conv layer on the MXU. x (N,H,W,C) int8; w (KH,KW,C,O) int8."""
    n = x.shape[0]
    kh, kw, c, o = w.shape
    patches, (ho, wo) = im2col(x, kh, kw, stride=stride, padding=padding)
    wmat = w.reshape(kh * kw * c, o)
    out = matmul_int8(patches, wmat, scale, bias, relu=relu,
                      out_dtype=out_dtype, interpret=interpret)
    return out.reshape(n, ho, wo, o)
