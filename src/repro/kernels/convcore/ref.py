"""Pure-jnp oracle for the convcore int8 GEMM + fused epilogue."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_int8_ref(a: jax.Array, b: jax.Array, scale: jax.Array,
                    bias: jax.Array, *, relu: bool = False,
                    out_dtype=jnp.bfloat16) -> jax.Array:
    acc = jnp.einsum("mk,kn->mn", a.astype(jnp.int32), b.astype(jnp.int32))
    out = acc.astype(jnp.float32) * scale[None, :] + bias[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(out_dtype)


def conv2d_int8_ref(x: jax.Array, w: jax.Array, scale: jax.Array,
                    bias: jax.Array, *, stride: int = 1, padding: int = 0,
                    relu: bool = False, out_dtype=jnp.bfloat16) -> jax.Array:
    """x (N, H, W, C) int8; w (KH, KW, C, O) int8 -> (N, H', W', O)."""
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * scale + bias
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(out_dtype)
