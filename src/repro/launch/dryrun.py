import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  This module is the only place the 512-device placeholder world is
# created; tests and benchmarks see the real single CPU device.
# ruff: noqa: E402
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell, two kinds of compiles:

1. **Validation compile** — the *full* configuration with rolled scans:
   proves the sharding config is coherent on the production mesh (a
   sharding mismatch / unsupported collective / layout conflict fails
   here), and yields ``memory_analysis`` for the fits-in-HBM check.

2. **Cost probes** — XLA's ``cost_analysis`` counts a ``while``-loop body
   exactly once, so a rolled-scan module under-reports per-step cost by
   the trip count.  Probes therefore lower *small unrolled* variants with
   ``num_layers = m`` and ``2m`` (m = pattern length) and reconstruct the
   full-depth cost affinely:

       total = probe1 + (n_groups - 1 + rem/m) * (probe2 - probe1)

   which is exact for FLOPs/collective-bytes (per-layer-group costs are
   identical) and a close approximation for bytes-accessed.  The same
   reconstruction applies to the collective inventory.

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import mesh as mesh_mod
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.specs import (
    abstract_decode_state,
    abstract_params,
    abstract_train_state,
    batch_shardings,
    batch_specs,
    param_sharding_tree,
    token_count,
)
from repro.models import pattern_split, prefill, slot_decode_step
from repro.sharding import activate_rules
from repro.train.optim import AdamWConfig
from repro.train.step import make_train_step
from repro.types import param_values

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../experiments/artifacts/dryrun")


# --------------------------------------------------------------------------
# lowering builders
# --------------------------------------------------------------------------
def build_lowered(cfg, shape, *, donate: bool = True, microbatches: int = 1):
    """Lower the cell's step function under the active mesh rules."""
    if shape.mode == "train":
        state, state_sh = abstract_train_state(cfg)
        batch = batch_specs(cfg, shape, with_labels=True)
        b_sh = batch_shardings(batch)
        step = make_train_step(cfg, AdamWConfig(), microbatches=microbatches)
        jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                         donate_argnums=(0,) if donate else ())
        return jitted.lower(state, batch)
    if shape.mode == "prefill":
        params_p = abstract_params(cfg)
        params = param_values(params_p)
        p_sh = param_sharding_tree(params_p)
        batch = batch_specs(cfg, shape, with_labels=False)
        b_sh = batch_shardings(batch)
        fn = lambda p, b: prefill(p, b, cfg, shape.seq_len)
        return jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(params, batch)
    # decode: per-slot positions (the serving engine's step)
    args, shardings = abstract_decode_state(cfg, shape)
    fn = lambda p, c, tok, ts: slot_decode_step(p, c, tok, ts, cfg)
    jitted = jax.jit(fn, in_shardings=shardings,
                     donate_argnums=(1,) if donate else ())
    return jitted.lower(*args)


def _memory_analysis(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": int(m.argument_size_in_bytes),
            "output_bytes": int(m.output_size_in_bytes),
            "temp_bytes": int(m.temp_size_in_bytes),
            "alias_bytes": int(m.alias_size_in_bytes),
        }
    except Exception as e:  # some backends lack memory_analysis
        return {"error": f"{type(e).__name__}: {e}"}


def _cost_analysis(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return {k: float(v) for k, v in c.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": f"{type(e).__name__}: {e}"}


def _probe_cfg(cfg, n_layers: int):
    kw = {"num_layers": n_layers, "unroll_scans": True}
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _probe_cost(cfg, shape, n_dev: int, *, microbatches: int = 1) -> dict:
    lowered = build_lowered(cfg, shape, donate=False,
                            microbatches=microbatches)
    compiled = lowered.compile()
    cost = _cost_analysis(compiled)
    stats = parse_collectives(compiled.as_text(), n_devices=n_dev)
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "wire_bytes": stats.wire_bytes,
        "coll_counts": stats.counts,
    }


def _reconstruct(p1: dict, p2: dict, scale: float) -> dict:
    out = {}
    for k in ("flops", "bytes", "wire_bytes"):
        out[k] = p1[k] + scale * (p2[k] - p1[k])
    out["coll_counts"] = {
        op: round(p1["coll_counts"].get(op, 0)
                  + scale * (p2["coll_counts"].get(op, 0)
                             - p1["coll_counts"].get(op, 0)))
        for op in set(p1["coll_counts"]) | set(p2["coll_counts"])}
    return out


def _model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS (6ND train, 2ND prefill, 2N/token decode)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * token_count(cfg, shape)
    if shape.mode == "train":
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             overrides: dict | None = None,
             rule_overrides: dict | None = None,
             microbatches: int = 1) -> dict:
    """rule_overrides: logical-axis -> mesh-axes mapping overrides (the
    hillclimb knob — e.g. {"act_seq": ("model",)} turns on sequence
    parallelism for activations/saved carries)."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "multipod" if multi_pod else "pod"
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.mode, "rule_overrides": rule_overrides}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        out["skipped"] = reason
        return out

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    out["n_devices"] = n_dev

    with activate_rules(mesh, rule_overrides) as rules:
        # ---- 1. validation compile: full config, rolled scans ----------
        t0 = time.time()
        lowered = build_lowered(cfg, shape, microbatches=microbatches)
        out["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        out["compile_s"] = round(time.time() - t1, 2)
        out["dropped_axes"] = sorted(str(d) for d in rules.dropped)
        out["memory"] = _memory_analysis(compiled)

        # ---- 2. cost probes: small unrolled variants --------------------
        pattern, n_full, rem = pattern_split(cfg)
        m = len(pattern)
        p1 = _probe_cost(_probe_cfg(cfg, m), shape, n_dev,
                         microbatches=microbatches)
        p2 = _probe_cost(_probe_cfg(cfg, 2 * m), shape, n_dev,
                         microbatches=microbatches)
        scale = (n_full - 1) + rem / m
        cost = _reconstruct(p1, p2, scale)
        out["probe"] = {"p1": p1, "p2": p2, "scale": scale}
        out["cost"] = cost

    out["roofline"] = roofline_terms(
        flops=cost["flops"], bytes_accessed=cost["bytes"],
        wire_bytes=cost["wire_bytes"],
        peak_flops=mesh_mod.PEAK_BF16_FLOPS, hbm_bw=mesh_mod.HBM_BW,
        link_bw=mesh_mod.ICI_BW)
    mf = _model_flops(cfg, shape)
    out["model_flops"] = mf
    hlo_total = cost["flops"] * n_dev
    out["model_flops_ratio"] = (mf / hlo_total) if hlo_total else None
    return out


def _write(out: dict, artifact_dir: str) -> str:
    d = os.path.join(artifact_dir, out["mesh"])
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{out['arch']}__{out['shape']}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"), default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--perf", action="store_true",
                    help="apply the tuned PERF_PRESETS where available "
                         "(writes artifacts under <out>-perf)")
    args = ap.parse_args()
    if args.perf and args.out == ARTIFACT_DIR:
        args.out = ARTIFACT_DIR + "-perf"

    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]
    cells = ([(a, s) for a in ARCHS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_name = "multipod" if mp else "pod"
            tag = f"{arch} x {shape_name} x {mesh_name}"
            path = os.path.join(args.out, mesh_name,
                                f"{arch}__{shape_name}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                if "error" not in prev:
                    print(f"[keep] {tag}")
                    continue
            kw = {}
            if args.perf:
                from repro.launch.presets import preset_for

                p = preset_for(arch, shape_name)
                if p:
                    kw = {"overrides": p.get("overrides") or None,
                          "rule_overrides": p.get("rule_overrides") or None,
                          "microbatches": p.get("microbatches", 1)}
            try:
                out = run_cell(arch, shape_name, mp, **kw)
            except Exception:
                out = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "error": traceback.format_exc()}
                failures += 1
                print(f"[FAIL] {tag}")
                print(out["error"].splitlines()[-1])
            else:
                if "skipped" in out:
                    print(f"[skip] {tag}: {out['skipped']}")
                else:
                    r = out["roofline"]
                    print(f"[ ok ] {tag}: compile {out['compile_s']}s "
                          f"dominant={r['dominant']} "
                          f"compute={r['compute_s']:.4f}s "
                          f"mem={r['memory_s']:.4f}s "
                          f"coll={r['collective_s']:.4f}s "
                          f"temp={out['memory'].get('temp_bytes', 0)/2**30:.1f}GiB",
                          flush=True)
            _write(out, args.out)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
