"""Tuned performance presets from the EXPERIMENTS.md §Perf hillclimbs.

Each entry is the *beyond-paper-baseline* configuration for a cell:
config-field overrides + sharding-rule overrides + microbatching.  The
paper-faithful baseline is always the no-preset run; ``dryrun --perf``
applies these on top so both are reproducible.
"""
from __future__ import annotations

PERF_PRESETS: dict = {
    # worst roofline fraction: 14 heads can't TP-shard on a 16-way axis ->
    # sequence parallelism + single-chunk attention + no-remat w/ 2
    # microbatches.  bound 16.57s -> 1.15s (14.4x), temp 22 -> 13.1 GiB.
    ("qwen2-0.5b", "train_4k"): {
        "overrides": {"attn_q_chunk": 4096, "remat": "none"},
        "rule_overrides": {"act_seq": ("model",)},
        "microbatches": 2,
    },
    # most collective-bound: TP of a 130M SSM is pure overhead -> 256-way
    # pure DP (batch over pod+data+model), SSM internals replicated.
    # collective 2.21s -> 0.023s (98x); bound 2.21 -> 0.355 (6.2x).
    ("mamba2-130m", "train_4k"): {
        "overrides": {},
        "rule_overrides": {
            "act_batch": ("pod", "data", "model"),
            "ssm_inner": (), "ssm_heads": (),
            "act_ssm_inner": (), "act_heads": (),
        },
        "microbatches": 1,
    },
    # paper-representative serving cell: kv=8 can't shard 16-way ->
    # sequence-sharded KV cache + explicit split-K decode attention +
    # int8 KV quantization.  footprint 86 GiB (infeasible) -> 9.5 GiB;
    # memory term 0.466 -> 0.173s (2.7x).
    ("grok-1-314b", "decode_32k"): {
        "overrides": {"kv_cache_dtype": "int8"},
        "rule_overrides": {"cache_seq": ("model",)},
        "microbatches": 1,
    },
}


def preset_for(arch: str, shape: str) -> dict | None:
    return PERF_PRESETS.get((arch, shape))
