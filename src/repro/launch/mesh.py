"""Production mesh construction.

Meshes are built by FUNCTIONS (never module-level constants) so importing
this module does not touch jax device state — required because the dry-run
must set ``XLA_FLAGS`` before the first jax device query.

Production topology (TPU v5e-like):
* single pod:  (16, 16)    -> ("data", "model")   256 chips
* multi-pod:   (2, 16, 16) -> ("pod", "data", "model")  512 chips

Axis semantics (see repro.sharding for the full rule table):
* ``model`` — tensor parallel: heads / mlp / vocab shard here; intra-pod,
  highest-bandwidth dimension.
* ``data`` — batch data parallel + parameter FSDP (weights' d_model dims
  shard over data+pod, ZeRO-3 style).
* ``pod``  — a second data-parallel axis across pods; gradient reduction
  over this axis crosses the slowest links (where the int8 compression
  codec applies).
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_sweep_mesh(devices=None):
    """1-D campaign-sweep mesh: every available device along a single
    ``"points"`` axis.  The sweep engine
    (``repro.core.sweep.interference_lane_metrics_batch``) shards its
    lane axis over it, so each device simulates an equal slice of a
    point batch — the run-farm analogue FireSim scales Fig. 5/6 with.

    ``devices=None`` uses all of ``jax.devices()``.  On a CPU-only host
    that is one device unless ``XLA_FLAGS=--xla_force_host_platform_
    device_count=N`` was exported before the first jax import (how
    tests and CI fan out to N lanes); a single-device mesh is valid —
    it just runs the whole batch on that device."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if not devices:
        raise RuntimeError(
            "no jax devices visible — on a CPU host export "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            "any jax import to fan the sweep mesh out to N lanes")
    return jax.sharding.Mesh(np.asarray(devices), ("points",))


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the real local devices (tests / examples)."""
    devices = jax.devices()
    n = len(devices)
    mp = max(1, min(model_parallel, n))
    dp = n // mp
    return jax.sharding.Mesh(
        np.asarray(devices[: dp * mp]).reshape(dp, mp), ("data", "model"))


# Hardware constants (TPU v5e-like target; used by roofline, not runtime)
PEAK_BF16_FLOPS = 197e12          # per chip
PEAK_INT8_OPS = 394e12            # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
HBM_BYTES = 16 * 1024**3          # per chip
VMEM_BYTES = 128 * 1024**2        # per core, tiling budget
