"""Post-SPMD HLO analysis: collective inventory + roofline terms.

``compiled.cost_analysis()`` exposes FLOPs and bytes-accessed for the
per-device module, but not collective traffic — that is parsed from the
optimized HLO text: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute occurrence is sized from its result type
and its replica-group size, and converted to *wire bytes per device* with
ring-algorithm cost formulas.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9,\[\]\{\}\s/]+?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum of byte sizes of every array shape in a (possibly tuple) type."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_bytes(type_str: str, op: str) -> int:
    """Bytes of the op *result*.  For -start tuples, the destination buffer
    is the last element; variadic collectives sum their elements."""
    shapes = _SHAPE_RE.findall(type_str)
    if not shapes:
        return 0
    if type_str.strip().startswith("(") and op in ("all-gather", "all-reduce",
                                                   "reduce-scatter"):
        # start-op tuple: (operand(s)..., results...); halves mirror, use half
        total = _shape_bytes(type_str)
        return total // 2
    return _shape_bytes(type_str)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [G, N] <= [T]: G groups of N
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict            # op -> count
    result_bytes: dict      # op -> sum of result bytes
    wire_bytes: float       # per-device ring-cost wire bytes
    by_group_size: dict     # (op, n) -> count


def parse_collectives(hlo_text: str, *, n_devices: int) -> CollectiveStats:
    counts: dict = {}
    result_bytes: dict = {}
    by_group: dict = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        type_str, op, start = m.group(1), m.group(2), m.group(3)
        if "-done" in line.split("=")[1][:40]:
            continue
        size = _result_bytes(type_str, op)
        n = _group_size(line, n_devices)
        counts[op] = counts.get(op, 0) + 1
        result_bytes[op] = result_bytes.get(op, 0) + size
        by_group[f"{op}/{n}"] = by_group.get(f"{op}/{n}", 0) + 1
        if n <= 1:
            continue
        frac = (n - 1) / n
        if op == "all-gather":
            wire += size * frac                     # result is the full buffer
        elif op == "all-reduce":
            wire += 2.0 * size * frac
        elif op == "reduce-scatter":
            wire += size * (n - 1)                  # result is the shard
        elif op == "all-to-all":
            wire += size * frac
        elif op == "collective-permute":
            wire += size
    return CollectiveStats(counts=counts, result_bytes=result_bytes,
                           wire_bytes=wire, by_group_size=by_group)


def roofline_terms(*, flops: float, bytes_accessed: float, wire_bytes: float,
                   peak_flops: float, hbm_bw: float, link_bw: float) -> dict:
    """The three per-device roofline terms, in seconds."""
    compute = flops / peak_flops
    memory = bytes_accessed / hbm_bw
    collective = wire_bytes / link_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "step_lower_bound_s": bound,
        "roofline_fraction": (compute / bound) if bound > 0 else 0.0,
    }
