"""Abstract input/state specs for AOT lowering (no device allocation).

Everything here is ``ShapeDtypeStruct``-valued: the dry-run lowers
``train_step`` / ``serve_step`` / ``prefill`` against these stand-ins and
compiles for the production mesh without materialising a single parameter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import init_caches, init_params
from repro.sharding import sharding_for
from repro.types import map_params, param_values


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_params(cfg: ModelConfig):
    """Param tree with ShapeDtypeStruct values (via eval_shape)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def token_count(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Text-token length for a cell (VLM cells reserve patch positions)."""
    s = shape.seq_len
    if cfg.family == "vlm" and cfg.num_patches:
        s -= cfg.num_patches
    return s


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, with_labels: bool):
    b = shape.global_batch
    s = token_count(cfg, shape)
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        batch["labels"] = _sds((b, s), jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = _sds((b, cfg.encoder_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm" and cfg.num_patches:
        batch["patches"] = _sds((b, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


def batch_shardings(batch: dict):
    """NamedSharding tree for a batch dict under the active rules."""
    axes = {
        "tokens": ("act_batch", None),
        "labels": ("act_batch", None),
        "frames": ("act_batch", None, None),
        "patches": ("act_batch", None, None),
    }
    return {k: sharding_for(v.shape, axes[k]) for k, v in batch.items()}


def param_sharding_tree(abstract):
    """Param tree (SDS values) -> NamedSharding tree (same treedef as values)."""
    return map_params(lambda p: sharding_for(p.value.shape, p.axes), abstract)


def abstract_train_state(cfg: ModelConfig):
    """(TrainState of SDS, matching sharding tree)."""
    from repro.train.step import TrainState

    params_p = abstract_params(cfg)
    values = param_values(params_p)
    shard = param_sharding_tree(params_p)
    fp32 = jax.tree.map(lambda v: _sds(v.shape, jnp.float32), values)
    rep = sharding_for((), ())
    state = TrainState(
        params=values,
        opt={"m": fp32, "v": fp32, "count": _sds((), jnp.int32)},
        step=_sds((), jnp.int32), ef=None)
    shardings = TrainState(
        params=shard, opt={"m": shard, "v": shard, "count": rep},
        step=rep, ef=None)
    return state, shardings


def abstract_decode_state(cfg: ModelConfig, shape: ShapeConfig):
    """((params, caches, tokens, ts) SDS tuple, matching shardings).

    The decode cell lowers the serving engine's per-slot step
    (``models.slot_decode_step``): each batch row carries its own
    position ``ts[i]``, so a continuous-batching scheduler can advance
    slots at different depths in one jitted call.
    """
    b = shape.global_batch
    params_p = abstract_params(cfg)
    caches_p = init_caches(cfg, b, shape.seq_len, abstract=True)
    token = _sds((b, 1), jnp.int32)
    ts = _sds((b,), jnp.int32)
    args = (param_values(params_p), param_values(caches_p), token, ts)
    shardings = (
        param_sharding_tree(params_p),
        param_sharding_tree(caches_p),
        sharding_for((b, 1), ("act_batch", None)),
        sharding_for((b,), ("act_batch",)),
    )
    return args, shardings
