"""End-to-end training driver with fault tolerance.

Presets:
* ``--preset smoke``  (default) — reduced model, quick on the CPU CI box;
* ``--preset 100m``   — a ~100M-param qwen2-family model for a few hundred
  steps; this is the configuration to run on a real TPU slice (on CPU it
  works but is slow);
* ``--arch <id>``     — any of the ten assigned architectures.

Demonstrates the production loop: deterministic resumable data, atomic
checkpoints, watchdog/straggler log, optional simulated failure.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse
import dataclasses

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig
from repro.train.loop import LoopConfig, train
from repro.train.optim import AdamWConfig


def preset_100m() -> ModelConfig:
    return ModelConfig(
        name="qwen2-100m", family="dense", num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32_000, attn_bias=True, act="silu", gated_mlp=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("smoke", "100m"), default="smoke")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) arch config")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="raise a simulated node failure at this step")
    args = ap.parse_args()

    if args.preset == "100m":
        cfg = preset_100m()
        args.batch, args.seq = max(args.batch, 8), max(args.seq, 256)
    elif args.full_config:
        cfg = get_config(args.arch)
    else:
        cfg = get_smoke_config(args.arch)

    loop_cfg = LoopConfig(total_steps=args.steps,
                          checkpoint_every=max(5, args.steps // 4),
                          checkpoint_dir=args.ckpt_dir, async_save=True,
                          log_every=max(1, args.steps // 20))

    def failure_hook(step):
        if step == args.inject_failure:
            args.inject_failure = -1
            raise RuntimeError(f"injected failure at step {step}")

    res = train(cfg, AdamWConfig(lr=3e-3, warmup_steps=10,
                                 decay_steps=max(100, args.steps)),
                loop_cfg, global_batch=args.batch, seq_len=args.seq,
                failure_hook=failure_hook if args.inject_failure >= 0 else None)
    print(f"\nfinal loss {res.losses[-1]:.4f} after {len(res.losses)} steps "
          f"({res.restarts} restarts, {len(res.straggler_steps)} straggler "
          f"events)")


if __name__ == "__main__":
    main()
