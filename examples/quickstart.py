"""Quickstart: the three things this framework does, in 60 seconds on CPU.

1. reproduce the paper's headline result (NVDLA running YOLOv3 behind a
   shared LLC: fps, LLC block-size effect, co-runner interference);
2. train a small LM with the production train step (any of the ten
   assigned architectures — here qwen2's reduced config);
3. serve it with batched prefill+decode.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_smoke_config
from repro.core import interference_sweep, llc_sweep, run_yolov3
from repro.data.synthetic import SyntheticStream
from repro.models import init_params
from repro.serve import Request, ServeEngine
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.types import param_values


def paper_experiments():
    print("== paper: NVDLA + RISC-V SoC on FireSim ==")
    r = run_yolov3()
    print(f"YOLOv3-416: accel {r.accel_s*1e3:.1f} ms + cpu {r.cpu_s*1e3:.1f} ms"
          f" -> {r.fps:.2f} fps   (paper: 67 ms + 66 ms -> 7.5 fps)")
    sw = llc_sweep(sizes_kib=(1024,), blocks=(32, 64, 128))
    sp = {b: sw["grid"][(1024, b)] for b in (32, 64, 128)}
    print(f"LLC 1 MiB speedup by block size: 32B {sp[32]:.2f}x  "
          f"64B {sp[64]:.2f}x  128B {sp[128]:.2f}x   (paper: 1.01/1.25/1.51)")
    isw = interference_sweep(corunners=(0, 4))
    print(f"4 BwWrite co-runners: LLC-WSS {isw['llc'][4]:.2f}x, "
          f"DRAM-WSS {isw['dram'][4]:.2f}x slowdown  (paper: 2.1x / 2.5x)")


def train_small_lm(steps=20):
    print("\n== train: qwen2 (reduced) ==")
    cfg = get_smoke_config("qwen2-0.5b")
    params = param_values(init_params(jax.random.PRNGKey(0), cfg))
    state = init_train_state(params)
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=100)))
    stream = SyntheticStream(cfg, global_batch=4, seq_len=64)
    for i in range(steps):
        state, m = step_fn(state, stream.batch_at(i))
        if i % 5 == 0:
            print(f"  step {i:3d}  loss {float(m['loss']):.3f}")
    return cfg, state


def serve_small_lm(cfg, state):
    print("\n== serve: continuous batching on the simulated SoC clock ==")
    import numpy as np

    eng = ServeEngine(cfg, state.params, cache_len=128, max_slots=2,
                      eos_id=0)
    rng = np.random.default_rng(7)
    for i in range(4):
        eng.submit(Request(
            rid=i, tokens=tuple(int(t) for t in
                                rng.integers(3, cfg.vocab_size, 32)),
            max_new=16, arrival_s=i * 1e-4))
    stats = eng.run()
    print(f"  served {stats.requests} requests / {stats.tokens} tokens "
          f"in {stats.steps} steps")
    print(f"  simulated: {stats.tokens_per_s:.0f} tok/s, "
          f"p50 {stats.latency_p50_s * 1e3:.3f} ms, "
          f"p99 {stats.latency_p99_s * 1e3:.3f} ms, "
          f"peak occupancy {stats.max_occupancy}")


if __name__ == "__main__":
    paper_experiments()
    cfg, state = train_small_lm()
    serve_small_lm(cfg, state)
    print("\nquickstart complete.")
