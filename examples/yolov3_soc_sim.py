"""YOLOv3 on the NVDLA/RISC-V SoC model — the paper's full case study.

Three parts:
1. the command stream (the accel/CPU split of all 107 layers),
2. a *numeric* int8 inference of a reduced YOLO stage through the
   convcore + postproc Pallas kernels (interpret mode) — validating the
   computation the perf model accounts for,
3. the three performance experiments (Figs 4/5/6).

Run:  PYTHONPATH=src python examples/yolov3_soc_sim.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    interference_sweep,
    llc_sweep,
    platform_table,
    run_yolov3,
)
from repro.core.quant import calibrate, quantize, quantize_conv_weights
from repro.core.runtime import compile_network
from repro.core.yolov3 import LAYERS, total_gops
from repro.kernels.convcore import conv2d_int8
from repro.kernels.convcore.ref import conv2d_int8_ref
from repro.kernels.postproc import postprocess


def show_command_stream():
    stream = compile_network()
    print(f"YOLOv3-416: {len(LAYERS)} layers, {total_gops():.1f} GOP/frame")
    print(f"  accelerator ops: {len(stream.accel_ops)} "
          f"(convs+shortcuts), traffic {stream.accel_traffic/1e6:.0f} MB")
    print(f"  cpu ops:         {len(stream.cpu_ops)} "
          f"(upsample/route/yolo/casts)")
    heavy = max(stream.accel_ops, key=lambda op: op.macs)
    print(f"  heaviest conv: layer {heavy.layer.index} "
          f"{heavy.layer.h}x{heavy.layer.w}x{heavy.layer.cin}"
          f"->{heavy.layer.cout}, {heavy.macs/1e9:.2f} GMAC, "
          f"{heavy.weight_passes} weight pass(es)")


def numeric_int8_stage():
    """Run darknet's first two conv layers numerically in int8 on the
    convcore kernel (reduced 64x64 input for CPU interpret mode)."""
    print("\nnumeric int8 stage (convcore + postproc kernels):")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 64, 64, 3), jnp.float32)
    sx = calibrate(x)
    xq = quantize(x, sx)
    acc = xq
    for i, (cout, k, stride) in enumerate([(32, 3, 1), (64, 3, 2)]):
        kw = jax.random.fold_in(key, i)
        w = jax.random.normal(kw, (k, k, acc.shape[-1], cout)) * 0.1
        wq, sw = quantize_conv_weights(w)
        scale = sx * sw
        out = conv2d_int8(acc, wq, scale, jnp.zeros((cout,)), stride=stride,
                          padding=1, relu=True, out_dtype=jnp.float32,
                          interpret=True)
        ref = conv2d_int8_ref(acc, wq, scale, jnp.zeros((cout,)),
                              stride=stride, padding=1, relu=True,
                              out_dtype=jnp.float32)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(f"  conv{i}: {acc.shape} -> {out.shape}, kernel==ref err {err:.2e}")
        sx = calibrate(out)
        acc = quantize(out, sx)
    pooled = postprocess(out, jnp.ones((out.shape[-1],)),
                         jnp.zeros((out.shape[-1],)), act="none", pool=2,
                         interpret=True)
    print(f"  postproc 2x2 maxpool: {out.shape} -> {pooled.shape}")


def performance_experiments():
    print("\nperformance experiments:")
    t = platform_table()
    for k, v in t.items():
        if k != "_meta":
            print(f"  {k:28s} {v:8.3f} fps")
    m = t["_meta"]
    print(f"  NVDLA split: {m['nvdla_accel_ms']:.1f} ms accel + "
          f"{m['nvdla_cpu_ms']:.1f} ms cpu (paper: 67 + 66)")

    sw = llc_sweep(sizes_kib=(0.5, 64, 1024, 4096), blocks=(32, 64, 128))
    print("  LLC speedup grid (vs no LLC):")
    for (size, block), sp in sorted(sw["grid"].items()):
        print(f"    {size:7.1f} KiB / {block:3d} B : {sp:.3f}x")

    isw = interference_sweep()
    print("  interference (normalized NVDLA time):")
    for wss in ("l1", "llc", "dram"):
        row = "  ".join(f"{isw[wss][n]:.2f}" for n in (0, 1, 2, 3, 4))
        print(f"    WSS={wss:4s}: {row}")


if __name__ == "__main__":
    show_command_stream()
    numeric_int8_stage()
    performance_experiments()
