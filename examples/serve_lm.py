"""Continuous-batching serving driver — the inference-engine shape of
the paper.

NVDLA is an inference offload engine behind a shared memory system; the
LM-serving analogue is a continuous-batching engine whose paged KV
blocks are the memory-system residents.  Requests arrive at an offered
load, queue for slots, and every scheduler step is priced by the SoC
latency oracle — so throughput and tail latency come out in *simulated
SoC seconds*, with LLC contention from slot occupancy visible in the
p99 (the paper's Fig. 6 effect, serving-side).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_smoke_config
from repro.models import init_params
from repro.serve import Request, ServeEngine
from repro.types import param_values


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--gap-us", type=float, default=100.0,
                    help="arrival gap between requests (simulated µs)")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = param_values(init_params(jax.random.PRNGKey(0), cfg))
    eng = ServeEngine(cfg, params,
                      cache_len=args.prompt_len + args.max_new + 8,
                      max_slots=args.max_slots, eos_id=0,
                      temperature=args.temperature)

    rng = np.random.default_rng(1)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            tokens=tuple(int(t) for t in
                         rng.integers(3, cfg.vocab_size, args.prompt_len)),
            max_new=args.max_new, arrival_s=i * args.gap_us * 1e-6))

    t0 = time.perf_counter()
    stats = eng.run()
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name}  requests={args.requests}  "
          f"slots={args.max_slots}  prompt={args.prompt_len}")
    print(f"host: {stats.tokens} tokens in {dt:.2f}s wall "
          f"({stats.tokens / dt:.1f} tok/s incl. compile)")
    print(f"simulated SoC: {stats.tokens_per_s:.0f} tok/s over "
          f"{stats.sim_time_s * 1e3:.3f} ms "
          f"(p50 {stats.latency_p50_s * 1e3:.3f} ms, "
          f"p99 {stats.latency_p99_s * 1e3:.3f} ms)")
    print(f"steps: {stats.prefill_steps} prefill / {stats.decode_steps} "
          f"decode / {stats.idle_steps} idle; "
          f"occupancy mean {stats.mean_occupancy:.2f} "
          f"max {stats.max_occupancy}")
    decode_hits = [r.llc_hit_rate for r in eng.step_log
                   if r.kind == "decode" and r.llc_hit_rate is not None]
    if decode_hits:
        print(f"decode LLC hit rate: min {min(decode_hits):.3f} "
              f"max {max(decode_hits):.3f}")
    sample = eng.finished[0]
    print(f"sample rid={sample['rid']}: {sample['tokens'][:10]} "
          f"(latency {sample['latency_s'] * 1e3:.3f} ms)")


if __name__ == "__main__":
    main()
