"""Batched serving driver — the inference-engine shape of the paper.

NVDLA is an inference offload engine behind a shared memory system; the
LM-serving analogue is a batched prefill+decode engine whose caches are
the memory-system residents.  This driver serves batched requests against
any assigned architecture and reports prefill/decode token throughput.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
"""
import argparse
import time

import jax

from repro.configs import ARCHS, get_smoke_config
from repro.data.synthetic import make_batch
from repro.models import init_params
from repro.serve import ServeEngine
from repro.types import param_values


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = param_values(init_params(jax.random.PRNGKey(0), cfg))
    eng = ServeEngine(cfg, params,
                      cache_len=args.prompt_len + args.max_new + 8,
                      eos_id=0, temperature=args.temperature)

    batch = make_batch(cfg, args.batch, args.prompt_len, seed=1)
    batch.pop("labels")

    t0 = time.perf_counter()
    res = eng.generate(batch, max_new=args.max_new)
    dt = time.perf_counter() - t0
    total_new = int(res.lengths.sum())
    print(f"arch={cfg.name}  batch={args.batch}  prompt={args.prompt_len}")
    print(f"generated {total_new} tokens in {res.steps} steps, {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")
    # steady-state decode rate (second call, compiled)
    t0 = time.perf_counter()
    res = eng.generate(batch, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"steady-state: {int(res.lengths.sum())/dt:.1f} tok/s")
    print("sample rows:", res.tokens[:2, :10].tolist())


if __name__ == "__main__":
    main()
